//! Binary columnar shard format — the out-of-core ingestion seam.
//!
//! The text path (`harness::load_splits` → `FeaturePartition::shard`) makes
//! every rank parse the *entire* libsvm file and then slice out its feature
//! block, which caps dataset size at one node's memory. `dglmnet convert`
//! writes the train split once as per-feature-block CSC segments so a
//! cluster rank can read *only its own block file plus the labels* — the
//! ingestion model of Trofimov & Genkin's system and of Mahajan et al.
//!
//! A shard directory holds:
//!
//! ```text
//! header.bin            DGSH | ver | name | base | n p nnz | seed kind M |
//!                       M × (len, sorted global col ids)          | fnv64
//! block-0000.bin ...    DGSB | ver | block n ncols nnz |
//!                       colptr u64[ncols+1] rowidx u32[nnz] values f64[nnz]
//!                                                                 | fnv64
//! labels.bin            DGSL | ver | n | y f64[n]                 | fnv64
//! rows-test.bin         DGSR | ver | n p nnz | CSR rows + labels  | fnv64
//! rows-validation.bin   (same layout as rows-test.bin)
//! ```
//!
//! All integers are fixed-width little-endian (mmap-friendly); every file
//! ends in an FNV-1a 64 checksum over all preceding bytes, so truncation and
//! bit flips are rejected before any structural validation runs. The header
//! carries the *full* partition (~8 bytes per feature), so any rank can
//! rebuild the global `FeaturePartition` from `header.bin` alone while its
//! matrix payload stays one block wide. Versioning rule: any layout change
//! bumps `FORMAT_VERSION` and readers reject other versions outright —
//! shard directories are cheap to regenerate from the source text.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, ensure, Context, Result};

use crate::data::dataset::{Dataset, Splits};
use crate::sparse::csc::Csc;
use crate::sparse::csr::Csr;
use crate::sparse::libsvm::MAX_FEATURE_INDEX;
use crate::sparse::partition::FeaturePartition;

/// Dataset-recipe prefix that selects this loader: `shards:<dir>`.
pub const RECIPE_PREFIX: &str = "shards:";

/// Bumped on any layout change; readers reject every other version.
pub const FORMAT_VERSION: u32 = 1;

/// Upper bound on the block count a shard directory may declare.
pub const MAX_BLOCKS: usize = 4096;

const HEADER_MAGIC: [u8; 4] = *b"DGSH";
const BLOCK_MAGIC: [u8; 4] = *b"DGSB";
const LABELS_MAGIC: [u8; 4] = *b"DGSL";
const ROWS_MAGIC: [u8; 4] = *b"DGSR";

/// No single length field may exceed this (1 TiB of elements) — bounds every
/// allocation a hostile file could request.
const MAX_LEN: u64 = 1 << 40;
const MAX_NAME_LEN: u64 = 4096;

/// `Some(dir)` when a dataset recipe selects shard ingestion.
pub fn shard_recipe(dataset: &str) -> Option<&str> {
    dataset.strip_prefix(RECIPE_PREFIX)
}

/// How the converter assigned features to blocks (recorded in the header).
/// Since the partition-strategy refactor this IS `sparse::PartitionStrategy`
/// — the header's kind tag, the CLI spelling, and the job-spec field all
/// name the same enum, resolved through `PartitionStrategy::resolve` in
/// exactly one place per run mode. Unknown header tags are still rejected
/// by `PartitionStrategy::from_tag`.
pub use crate::sparse::partition::PartitionStrategy as PartitionKind;

/// Parsed, validated `header.bin`.
#[derive(Clone, Debug)]
pub struct ShardHeader {
    /// Base dataset name (without the `-train` suffix).
    pub name: String,
    /// Index base of the source text file (0 or 1) — provenance only; all
    /// binary ids are 0-based.
    pub index_base: u64,
    /// Train rows.
    pub n: usize,
    /// Features (global).
    pub p: usize,
    /// Train nonzeros (global).
    pub nnz: usize,
    /// Seed the partition (and, for named corpora, the data) derives from.
    pub seed: u64,
    pub kind: PartitionKind,
    /// Global feature partition, rebuilt from the header's block lists.
    pub partition: FeaturePartition,
}

/// Bytes a loader actually pulled off disk — the out-of-core accounting the
/// done report and the acceptance tests assert on.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadStats {
    pub bytes_read: u64,
}

pub fn header_path(dir: &Path) -> PathBuf {
    dir.join("header.bin")
}

pub fn block_path(dir: &Path, rank: usize) -> PathBuf {
    dir.join(format!("block-{rank:04}.bin"))
}

pub fn labels_path(dir: &Path) -> PathBuf {
    dir.join("labels.bin")
}

pub fn rows_path(dir: &Path, split: &str) -> PathBuf {
    dir.join(format!("rows-{split}.bin"))
}

/// FNV-1a 64 over a byte slice — the per-file trailing checksum.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append the checksum and write atomically (tmp file + rename, like the
/// DGCK checkpoints) so a crashed convert never leaves a half-written shard
/// that passes its checksum. Returns the on-disk byte count.
fn write_file_checked(path: &Path, mut body: Vec<u8>) -> Result<u64> {
    let sum = fnv1a(&body);
    push_u64(&mut body, sum);
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            fs::create_dir_all(parent)
                .with_context(|| format!("creating shard dir {}", parent.display()))?;
        }
    }
    let file_name = path
        .file_name()
        .ok_or_else(|| anyhow!("shard path {} has no file name", path.display()))?
        .to_string_lossy()
        .to_string();
    let tmp = path.with_file_name(format!(".{file_name}.tmp"));
    {
        let mut f = fs::File::create(&tmp)
            .with_context(|| format!("creating shard file {}", tmp.display()))?;
        f.write_all(&body)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)
        .with_context(|| format!("publishing shard file {}", path.display()))?;
    Ok(body.len() as u64)
}

/// Read a shard file, verify checksum → magic → version (in that order),
/// and return the raw bytes plus the count read.
fn read_file_checked(path: &Path, magic: [u8; 4]) -> Result<(Vec<u8>, u64)> {
    let raw =
        fs::read(path).with_context(|| format!("reading shard file {}", path.display()))?;
    ensure!(
        raw.len() as u64 <= MAX_LEN,
        "shard file {} is implausibly large ({} bytes)",
        path.display(),
        raw.len()
    );
    ensure!(
        raw.len() >= 16,
        "shard file {} too short ({} bytes) to hold magic, version and checksum",
        path.display(),
        raw.len()
    );
    let (body, tail) = raw.split_at(raw.len() - 8);
    let want = u64::from_le_bytes(tail.try_into().unwrap());
    let got = fnv1a(body);
    ensure!(
        got == want,
        "shard file {} failed its checksum (stored {want:#018x}, computed {got:#018x}) — truncated or corrupt",
        path.display()
    );
    ensure!(
        body[..4] == magic,
        "shard file {} has wrong magic {:?} (expected {:?})",
        path.display(),
        &body[..4],
        &magic
    );
    let version = u32::from_le_bytes(body[4..8].try_into().unwrap());
    ensure!(
        version == FORMAT_VERSION,
        "shard file {}: unsupported format version {version} (this build reads v{FORMAT_VERSION})",
        path.display()
    );
    let bytes_read = raw.len() as u64;
    Ok((raw, bytes_read))
}

/// Cursor over a checked file's payload (past magic+version, before the
/// checksum), with every read bounds-checked.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    path: &'a Path,
}

impl<'a> Reader<'a> {
    fn over(raw: &'a [u8], path: &'a Path) -> Reader<'a> {
        Reader {
            buf: &raw[..raw.len() - 8],
            pos: 8,
            path,
        }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| anyhow!("shard file {}: length overflow", self.path.display()))?;
        ensure!(
            end <= self.buf.len(),
            "truncated shard file {}: wanted {n} bytes at offset {}, have {}",
            self.path.display(),
            self.pos,
            self.buf.len()
        );
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A u64 length/count field, rejected above `max` before it can drive an
    /// allocation.
    fn usize_bounded(&mut self, what: &str, max: u64) -> Result<usize> {
        let v = self.u64()?;
        ensure!(
            v <= max,
            "shard file {}: {what} {v} exceeds the bound {max}",
            self.path.display()
        );
        Ok(v as usize)
    }

    /// Every payload byte must be consumed — trailing garbage is rejected.
    fn done(&self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "shard file {}: {} trailing bytes after the payload",
            self.path.display(),
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

/// The partition must be a disjoint cover of `0..p` with sorted blocks.
fn validate_blocks(blocks: &[Vec<usize>], p: usize) -> Result<()> {
    let mut seen = vec![false; p];
    let mut covered = 0usize;
    for (r, block) in blocks.iter().enumerate() {
        let mut prev: Option<usize> = None;
        for &j in block {
            ensure!(j < p, "block {r} names feature {j} but the dataset has only {p}");
            if let Some(q) = prev {
                ensure!(j > q, "block {r} is not sorted strictly increasing at feature {j}");
            }
            prev = Some(j);
            ensure!(!seen[j], "feature {j} appears in more than one block");
            seen[j] = true;
            covered += 1;
        }
    }
    ensure!(
        covered == p,
        "blocks cover {covered} of {p} features — the partition must be a disjoint cover"
    );
    Ok(())
}

/// What `write_shards` put on disk, for the converter's summary line.
#[derive(Clone, Debug)]
pub struct ShardWriteReport {
    pub files: usize,
    pub bytes: u64,
    /// Per-block column counts.
    pub block_cols: Vec<usize>,
    /// Per-block nonzero counts.
    pub block_nnz: Vec<usize>,
}

/// Write a full shard directory for `splits` under `partition`.
pub fn write_shards(
    dir: &Path,
    splits: &Splits,
    partition: &FeaturePartition,
    kind: PartitionKind,
    seed: u64,
    index_base: u64,
) -> Result<ShardWriteReport> {
    let train = &splits.train;
    let (n, p, nnz) = (train.n(), train.p(), train.nnz());
    ensure!(
        p <= MAX_FEATURE_INDEX + 1,
        "dataset has {p} features, above the supported bound {}",
        MAX_FEATURE_INDEX + 1
    );
    ensure!(
        partition.num_features() == p,
        "partition covers {} features but the train split has {p}",
        partition.num_features()
    );
    let m = partition.num_nodes();
    ensure!(
        (1..=MAX_BLOCKS).contains(&m),
        "block count {m} out of range 1..={MAX_BLOCKS}"
    );
    ensure!(index_base <= 1, "index base must be 0 or 1, got {index_base}");
    validate_blocks(&partition.blocks, p)?;

    let name = train.name.strip_suffix("-train").unwrap_or(&train.name);
    ensure!(
        name.len() as u64 <= MAX_NAME_LEN,
        "dataset name is longer than {MAX_NAME_LEN} bytes"
    );
    let mut files = 0usize;
    let mut bytes = 0u64;

    let mut b = Vec::new();
    b.extend_from_slice(&HEADER_MAGIC);
    push_u32(&mut b, FORMAT_VERSION);
    push_u64(&mut b, name.len() as u64);
    b.extend_from_slice(name.as_bytes());
    push_u64(&mut b, index_base);
    push_u64(&mut b, n as u64);
    push_u64(&mut b, p as u64);
    push_u64(&mut b, nnz as u64);
    push_u64(&mut b, seed);
    push_u64(&mut b, kind.tag());
    push_u64(&mut b, m as u64);
    for block in &partition.blocks {
        push_u64(&mut b, block.len() as u64);
        for &j in block {
            push_u64(&mut b, j as u64);
        }
    }
    bytes += write_file_checked(&header_path(dir), b)?;
    files += 1;

    let x_csc = train.to_csc();
    let mut block_cols = Vec::with_capacity(m);
    let mut block_nnz = Vec::with_capacity(m);
    for r in 0..m {
        let shard = partition.shard(&x_csc, r);
        block_cols.push(shard.ncols);
        block_nnz.push(shard.nnz());
        let mut b = Vec::with_capacity(40 + 8 * (shard.ncols + 1) + 12 * shard.nnz());
        b.extend_from_slice(&BLOCK_MAGIC);
        push_u32(&mut b, FORMAT_VERSION);
        push_u64(&mut b, r as u64);
        push_u64(&mut b, shard.nrows as u64);
        push_u64(&mut b, shard.ncols as u64);
        push_u64(&mut b, shard.nnz() as u64);
        for &cp in &shard.colptr {
            push_u64(&mut b, cp as u64);
        }
        for &ri in &shard.rowidx {
            push_u32(&mut b, ri);
        }
        for &v in &shard.values {
            push_f64(&mut b, v);
        }
        bytes += write_file_checked(&block_path(dir, r), b)?;
        files += 1;
    }

    let mut b = Vec::with_capacity(24 + 8 * n);
    b.extend_from_slice(&LABELS_MAGIC);
    push_u32(&mut b, FORMAT_VERSION);
    push_u64(&mut b, n as u64);
    for &v in &train.y {
        push_f64(&mut b, v);
    }
    bytes += write_file_checked(&labels_path(dir), b)?;
    files += 1;

    for (split, ds) in [("test", &splits.test), ("validation", &splits.validation)] {
        ensure!(
            ds.p() == p,
            "{split} split has {} features but train has {p}",
            ds.p()
        );
        let mut b =
            Vec::with_capacity(48 + 8 * (ds.n() + 2) + 12 * ds.nnz() + 8 * ds.n());
        b.extend_from_slice(&ROWS_MAGIC);
        push_u32(&mut b, FORMAT_VERSION);
        push_u64(&mut b, ds.n() as u64);
        push_u64(&mut b, p as u64);
        push_u64(&mut b, ds.nnz() as u64);
        for &rp in &ds.x.rowptr {
            push_u64(&mut b, rp as u64);
        }
        for &ci in &ds.x.colidx {
            push_u32(&mut b, ci);
        }
        for &v in &ds.x.values {
            push_f64(&mut b, v);
        }
        for &v in &ds.y {
            push_f64(&mut b, v);
        }
        bytes += write_file_checked(&rows_path(dir, split), b)?;
        files += 1;
    }

    Ok(ShardWriteReport {
        files,
        bytes,
        block_cols,
        block_nnz,
    })
}

/// Parse and validate `header.bin`. Reads ~8 bytes per global feature —
/// never a matrix payload.
pub fn open_header(dir: &Path) -> Result<ShardHeader> {
    let path = header_path(dir);
    let (raw, _) = read_file_checked(&path, HEADER_MAGIC)?;
    let mut r = Reader::over(&raw, &path);
    let name_len = r.usize_bounded("dataset name length", MAX_NAME_LEN)?;
    let name = String::from_utf8(r.take(name_len)?.to_vec())
        .map_err(|_| anyhow!("shard header holds a non-UTF-8 dataset name"))?;
    let index_base = r.u64()?;
    ensure!(index_base <= 1, "shard header index base {index_base} (must be 0 or 1)");
    let n = r.usize_bounded("row count", MAX_LEN)?;
    // Same bound the libsvm text parser enforces on raw indices.
    let p = r.usize_bounded("feature count", (MAX_FEATURE_INDEX as u64) + 1)?;
    let nnz = r.usize_bounded("nnz", MAX_LEN)?;
    let seed = r.u64()?;
    let kind = PartitionKind::from_tag(r.u64()?)?;
    let m = r.usize_bounded("block count", MAX_BLOCKS as u64)?;
    ensure!(m >= 1, "shard header declares zero blocks");
    let mut blocks = Vec::with_capacity(m);
    for _ in 0..m {
        let len = r.usize_bounded("block length", p as u64)?;
        let mut block = Vec::with_capacity(len);
        for _ in 0..len {
            block.push(r.usize_bounded("feature id", p as u64)?);
        }
        blocks.push(block);
    }
    r.done()?;
    validate_blocks(&blocks, p)?;
    let mut owner = vec![0usize; p];
    for (rk, block) in blocks.iter().enumerate() {
        for &j in block {
            owner[j] = rk;
        }
    }
    Ok(ShardHeader {
        name,
        index_base,
        n,
        p,
        nnz,
        seed,
        kind,
        partition: FeaturePartition { blocks, owner },
    })
}

impl ShardHeader {
    pub fn num_blocks(&self) -> usize {
        self.partition.num_nodes()
    }

    /// Load one rank's CSC block — the only train-matrix bytes that rank
    /// ever touches.
    pub fn load_block(&self, dir: &Path, rank: usize) -> Result<(Csc, LoadStats)> {
        ensure!(
            rank < self.num_blocks(),
            "rank {rank} out of range: shard dir holds {} blocks",
            self.num_blocks()
        );
        let path = block_path(dir, rank);
        let (raw, bytes_read) = read_file_checked(&path, BLOCK_MAGIC)?;
        let mut r = Reader::over(&raw, &path);
        let idx = r.u64()?;
        ensure!(
            idx == rank as u64,
            "shard file {}: holds block {idx}, expected {rank}",
            path.display()
        );
        let n = r.usize_bounded("block row count", MAX_LEN)?;
        ensure!(
            n == self.n,
            "shard file {}: {n} rows but the header declares {}",
            path.display(),
            self.n
        );
        let ncols = r.usize_bounded("block column count", self.p as u64)?;
        ensure!(
            ncols == self.partition.blocks[rank].len(),
            "shard file {}: {ncols} columns but the header's block {rank} lists {}",
            path.display(),
            self.partition.blocks[rank].len()
        );
        let nnz = r.usize_bounded("block nnz", self.nnz as u64)?;
        let mut colptr = Vec::with_capacity(ncols + 1);
        for _ in 0..=ncols {
            colptr.push(r.usize_bounded("colptr entry", nnz as u64)?);
        }
        ensure!(
            colptr[0] == 0 && colptr[ncols] == nnz,
            "shard file {}: colptr must run 0..{nnz}",
            path.display()
        );
        ensure!(
            colptr.windows(2).all(|w| w[0] <= w[1]),
            "shard file {}: colptr is not monotone",
            path.display()
        );
        let mut rowidx = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            let ri = r.u32()?;
            ensure!(
                (ri as usize) < n,
                "shard file {}: row id {ri} out of range (n={n})",
                path.display()
            );
            rowidx.push(ri);
        }
        let mut values = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            values.push(r.f64()?);
        }
        r.done()?;
        Ok((
            Csc {
                nrows: n,
                ncols,
                colptr,
                rowidx,
                values,
            },
            LoadStats { bytes_read },
        ))
    }

    /// Load the shared train labels.
    pub fn load_labels(&self, dir: &Path) -> Result<(Vec<f64>, LoadStats)> {
        let path = labels_path(dir);
        let (raw, bytes_read) = read_file_checked(&path, LABELS_MAGIC)?;
        let mut r = Reader::over(&raw, &path);
        let n = r.usize_bounded("label count", MAX_LEN)?;
        ensure!(
            n == self.n,
            "shard file {}: {n} labels but the header declares {}",
            path.display(),
            self.n
        );
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            y.push(r.f64()?);
        }
        r.done()?;
        Ok((y, LoadStats { bytes_read }))
    }

    /// Load an eval split (`"test"` or `"validation"`) as full CSR rows —
    /// the small held-out sets, not the train matrix.
    pub fn load_rows(&self, dir: &Path, split: &str) -> Result<(Dataset, LoadStats)> {
        ensure!(
            split == "test" || split == "validation",
            "unknown shard row split '{split}' (expected test|validation)"
        );
        let path = rows_path(dir, split);
        let (raw, bytes_read) = read_file_checked(&path, ROWS_MAGIC)?;
        let mut r = Reader::over(&raw, &path);
        let n = r.usize_bounded("row count", MAX_LEN)?;
        let p = r.usize_bounded("feature count", (MAX_FEATURE_INDEX as u64) + 1)?;
        ensure!(
            p == self.p,
            "shard file {}: {p} features but the header declares {}",
            path.display(),
            self.p
        );
        let nnz = r.usize_bounded("nnz", MAX_LEN)?;
        let mut rowptr = Vec::with_capacity(n + 1);
        for _ in 0..=n {
            rowptr.push(r.usize_bounded("rowptr entry", nnz as u64)?);
        }
        ensure!(
            rowptr[0] == 0 && rowptr[n] == nnz,
            "shard file {}: rowptr must run 0..{nnz}",
            path.display()
        );
        ensure!(
            rowptr.windows(2).all(|w| w[0] <= w[1]),
            "shard file {}: rowptr is not monotone",
            path.display()
        );
        let mut colidx = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            let ci = r.u32()?;
            ensure!(
                (ci as usize) < p,
                "shard file {}: column id {ci} out of range (p={p})",
                path.display()
            );
            colidx.push(ci);
        }
        let mut values = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            values.push(r.f64()?);
        }
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            y.push(r.f64()?);
        }
        r.done()?;
        let x = Csr {
            nrows: n,
            ncols: p,
            rowptr,
            colidx,
            values,
        };
        Ok((
            Dataset::new(format!("{}-{split}", self.name), x, y),
            LoadStats { bytes_read },
        ))
    }
}

/// Assemble the *full* `Splits` from a shard directory — the single-process
/// convenience path behind `load_splits("shards:<dir>")`. Cluster ranks use
/// `load_block` instead and never call this.
pub fn load_splits_full(dir: &Path) -> Result<Splits> {
    let h = open_header(dir)?;
    let (y, _) = h.load_labels(dir)?;
    let mut colptr = vec![0usize; h.p + 1];
    let mut shards = Vec::with_capacity(h.num_blocks());
    for rk in 0..h.num_blocks() {
        let (csc, _) = h.load_block(dir, rk)?;
        for (k, &j) in h.partition.blocks[rk].iter().enumerate() {
            colptr[j + 1] = csc.col_nnz(k);
        }
        shards.push(csc);
    }
    for j in 0..h.p {
        colptr[j + 1] += colptr[j];
    }
    let total = colptr[h.p];
    ensure!(
        total == h.nnz,
        "shard blocks hold {total} nonzeros but the header declares {}",
        h.nnz
    );
    let mut rowidx = vec![0u32; total];
    let mut values = vec![0f64; total];
    for (rk, csc) in shards.iter().enumerate() {
        for (k, &j) in h.partition.blocks[rk].iter().enumerate() {
            let (rows, vals) = csc.col_raw(k);
            let dst = colptr[j];
            rowidx[dst..dst + rows.len()].copy_from_slice(rows);
            values[dst..dst + vals.len()].copy_from_slice(vals);
        }
    }
    let train_csc = Csc {
        nrows: h.n,
        ncols: h.p,
        colptr,
        rowidx,
        values,
    };
    let train = Dataset::new(format!("{}-train", h.name), train_csc.to_csr(), y);
    let (test, _) = h.load_rows(dir, "test")?;
    let (validation, _) = h.load_rows(dir, "validation")?;
    Ok(Splits {
        train,
        test,
        validation,
    })
}

/// What `convert_recipe` produced, for the CLI summary and the tests.
#[derive(Clone, Debug)]
pub struct ConvertReport {
    pub name: String,
    pub n: usize,
    pub p: usize,
    pub nnz: usize,
    pub blocks: usize,
    pub kind: PartitionKind,
    pub write: ShardWriteReport,
}

/// `dglmnet convert` in library form: resolve any text dataset recipe, build
/// the requested partition over its train split, and write a shard dir.
pub fn convert_recipe(
    dataset: &str,
    scale: f64,
    seed: u64,
    blocks: usize,
    kind: PartitionKind,
    out: &Path,
) -> Result<ConvertReport> {
    ensure!(
        shard_recipe(dataset).is_none(),
        "'{dataset}' is already a shard directory — convert takes a libsvm path or a named corpus"
    );
    ensure!(
        (1..=MAX_BLOCKS).contains(&blocks),
        "--blocks must be in 1..={MAX_BLOCKS}, got {blocks}"
    );
    let splits = crate::harness::load_splits(dataset, scale, seed)?;
    let p = splits.train.p();
    // The single partition-resolution call site for `dglmnet convert`.
    let partition = kind.resolve(&splits.train.to_csc(), blocks, seed);
    // Named corpora are synthesized in memory (base 0); anything else came
    // through the 1-based libsvm text reader.
    let named = matches!(dataset, "epsilon_like" | "webspam_like" | "clickstream");
    let index_base = if named { 0 } else { 1 };
    let write = write_shards(out, &splits, &partition, kind, seed, index_base)?;
    Ok(ConvertReport {
        name: splits
            .train
            .name
            .strip_suffix("-train")
            .unwrap_or(&splits.train.name)
            .to_string(),
        n: splits.train.n(),
        p,
        nnz: splits.train.nnz(),
        blocks,
        kind,
        write,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::libsvm::{self, IndexBase, LibsvmData};
    use crate::util::prop;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("dglmnet-shards-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    /// A tiny deterministic Splits built straight from row data.
    fn splits_from_rows(
        name: &str,
        nc: usize,
        rows: &[Vec<(usize, f64)>],
        y: &[f64],
    ) -> Splits {
        let train = Dataset::new(
            format!("{name}-train"),
            crate::sparse::csr::Csr::from_rows(nc, rows),
            y.to_vec(),
        );
        let eval = |tag: &str| {
            Dataset::new(
                format!("{name}-{tag}"),
                crate::sparse::csr::Csr::from_rows(nc, &[vec![(0, 1.0)]]),
                vec![1.0],
            )
        };
        Splits {
            train,
            test: eval("test"),
            validation: eval("validation"),
        }
    }

    #[test]
    fn prop_shard_roundtrip_bit_identical_to_text_parse() {
        // The acceptance property: text parse → convert → load reproduces
        // the parsed matrix *bit for bit*, under both libsvm index bases.
        for (case, base) in [(0usize, IndexBase::Zero), (1, IndexBase::One)] {
            let dir = tmp_dir(&format!("prop-{case}"));
            prop::check("shard write→load round-trip", 25, |rng| {
                let (nr, nc) = (1 + rng.below(10), 1 + rng.below(12));
                let rows: Vec<Vec<(usize, f64)>> =
                    (0..nr).map(|_| prop::sparse_vec(rng, nc, 6, 4.0)).collect();
                let y: Vec<f64> = (0..nr)
                    .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
                    .collect();
                let d = LibsvmData {
                    x: crate::sparse::csr::Csr::from_rows(nc, &rows),
                    y,
                };
                // Reference = the text parse of a real libsvm byte stream.
                let mut text = Vec::new();
                libsvm::write_with_base(&mut text, &d, base)
                    .map_err(|e| format!("write: {e}"))?;
                let parsed =
                    libsvm::read(text.as_slice(), base, nc).map_err(|e| format!("read: {e}"))?;

                let m = 1 + rng.below(4);
                let splits = splits_from_rows("prop", nc, &rows, &parsed.y);
                let partition = FeaturePartition::hashed(nc, m, 7);
                let ibase = match base {
                    IndexBase::Zero => 0,
                    IndexBase::One => 1,
                };
                write_shards(&dir, &splits, &partition, PartitionKind::Hashed, 7, ibase)
                    .map_err(|e| format!("write_shards: {e}"))?;

                let h = open_header(&dir).map_err(|e| format!("open_header: {e}"))?;
                if h.index_base != ibase || h.p != nc || h.n != nr {
                    return Err(format!(
                        "header mismatch: base {} p {} n {}",
                        h.index_base, h.p, h.n
                    ));
                }
                // Full reassembly is bit-identical to the text parse.
                let full = load_splits_full(&dir).map_err(|e| format!("load_splits_full: {e}"))?;
                if full.train.x != parsed.x {
                    return Err("reassembled train matrix differs from text parse".into());
                }
                let same_bits = full
                    .train
                    .y
                    .iter()
                    .zip(parsed.y.iter())
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                if !same_bits {
                    return Err("labels differ from text parse".into());
                }
                // Every block is bit-identical to sharding the parsed matrix.
                let x_csc = parsed.x.to_csc();
                for r in 0..m {
                    let (blk, stats) =
                        h.load_block(&dir, r).map_err(|e| format!("load_block {r}: {e}"))?;
                    if blk != partition.shard(&x_csc, r) {
                        return Err(format!("block {r} differs from in-memory shard"));
                    }
                    if stats.bytes_read == 0 {
                        return Err("block load reported zero bytes".into());
                    }
                }
                Ok(())
            });
            let _ = fs::remove_dir_all(&dir);
        }
    }

    fn demo_splits() -> (Splits, FeaturePartition) {
        let rows = vec![
            vec![(0, 1.0), (3, -2.0)],
            vec![(1, 0.5)],
            vec![(2, 3.25), (4, 1.0)],
            vec![(0, -1.5), (4, 2.0)],
        ];
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let splits = splits_from_rows("demo", 5, &rows, &y);
        let partition = FeaturePartition::hashed(5, 2, 3);
        (splits, partition)
    }

    #[test]
    fn truncated_and_bit_flipped_files_are_rejected() {
        let dir = tmp_dir("corrupt");
        let (splits, partition) = demo_splits();
        write_shards(&dir, &splits, &partition, PartitionKind::Hashed, 3, 0).unwrap();
        let h = open_header(&dir).unwrap();
        h.load_block(&dir, 0).unwrap();

        for path in [
            header_path(&dir),
            block_path(&dir, 0),
            labels_path(&dir),
            rows_path(&dir, "test"),
        ] {
            let good = fs::read(&path).unwrap();
            // Truncation at several depths, including mid-checksum.
            for cut in [0usize, 3, 8, good.len() / 2, good.len() - 8, good.len() - 1] {
                fs::write(&path, &good[..cut]).unwrap();
                assert!(
                    open_header(&dir).is_err()
                        || h.load_block(&dir, 0).is_err()
                        || h.load_labels(&dir).is_err()
                        || h.load_rows(&dir, "test").is_err(),
                    "truncation at {cut} of {} accepted",
                    path.display()
                );
            }
            // A single flipped bit anywhere must fail the checksum.
            for at in [4usize, 12, good.len() / 2, good.len() - 9] {
                let mut bad = good.clone();
                bad[at] ^= 0x10;
                fs::write(&path, &bad).unwrap();
                let all = (
                    open_header(&dir),
                    h.load_block(&dir, 0),
                    h.load_labels(&dir),
                    h.load_rows(&dir, "test"),
                );
                assert!(
                    all.0.is_err() || all.1.is_err() || all.2.is_err() || all.3.is_err(),
                    "bit flip at {at} of {} accepted",
                    path.display()
                );
            }
            fs::write(&path, &good).unwrap();
        }
        // Restored directory loads cleanly again.
        assert!(load_splits_full(&dir).is_ok());
        let _ = fs::remove_dir_all(&dir);
    }

    /// Re-checksum a tampered body so structural validation (not the
    /// checksum) must catch it.
    fn rewrite_checked(path: &Path, mut body: Vec<u8>) {
        let sum = fnv1a(&body);
        push_u64(&mut body, sum);
        fs::write(path, body).unwrap();
    }

    #[test]
    fn header_validator_rejects_bad_partitions_and_huge_dims() {
        let dir = tmp_dir("validate");
        let (splits, partition) = demo_splits();
        write_shards(&dir, &splits, &partition, PartitionKind::Hashed, 3, 0).unwrap();
        let good = fs::read(header_path(&dir)).unwrap();
        let body = &good[..good.len() - 8];
        // Layout past magic+ver: name_len(8) name(4:"demo") base n p nnz
        // seed kind m …
        let p_off = 8 + 8 + 4 + 8 + 8;

        // Feature count above the libsvm bound.
        let mut bad = body.to_vec();
        bad[p_off..p_off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        rewrite_checked(&header_path(&dir), bad);
        let err = open_header(&dir).unwrap_err().to_string();
        assert!(err.contains("feature count"), "got: {err}");

        // Duplicate feature across blocks: patch the first id of block 0's
        // list to equal its second (blocks start after m at a fixed offset).
        let blocks_off = p_off + 8 * 5;
        let len0 =
            u64::from_le_bytes(body[blocks_off..blocks_off + 8].try_into().unwrap()) as usize;
        if len0 >= 2 {
            let mut bad = body.to_vec();
            let first = blocks_off + 8;
            let second = body[first + 8..first + 16].to_vec();
            bad[first..first + 8].copy_from_slice(&second);
            rewrite_checked(&header_path(&dir), bad);
            let err = open_header(&dir).unwrap_err().to_string();
            assert!(
                err.contains("more than one block") || err.contains("sorted"),
                "got: {err}"
            );
        }

        fs::write(header_path(&dir), &good).unwrap();
        assert!(open_header(&dir).is_ok());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn block_and_label_cross_checks_fire() {
        let dir = tmp_dir("cross");
        let (splits, partition) = demo_splits();
        write_shards(&dir, &splits, &partition, PartitionKind::Hashed, 3, 0).unwrap();
        let h = open_header(&dir).unwrap();
        // Wrong-rank read: block 1's file served as block 0.
        let blk1 = fs::read(block_path(&dir, 1)).unwrap();
        fs::write(block_path(&dir, 0), &blk1).unwrap();
        let err = h.load_block(&dir, 0).unwrap_err().to_string();
        assert!(err.contains("holds block 1"), "got: {err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_version_is_rejected() {
        let dir = tmp_dir("version");
        let (splits, partition) = demo_splits();
        write_shards(&dir, &splits, &partition, PartitionKind::Hashed, 3, 0).unwrap();
        let good = fs::read(labels_path(&dir)).unwrap();
        let mut body = good[..good.len() - 8].to_vec();
        body[4..8].copy_from_slice(&99u32.to_le_bytes());
        rewrite_checked(&labels_path(&dir), body);
        let h = open_header(&dir).unwrap();
        let err = h.load_labels(&dir).unwrap_err().to_string();
        assert!(err.contains("unsupported format version 99"), "got: {err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn convert_recipe_matches_text_corpus() {
        let dir = tmp_dir("convert");
        let rep =
            convert_recipe("epsilon_like", 0.03, 5, 3, PartitionKind::Hashed, &dir).unwrap();
        assert_eq!(rep.blocks, 3);
        // header + 3 blocks + labels + rows-test + rows-validation
        assert_eq!(rep.write.files, 7);
        let text = crate::harness::load_splits("epsilon_like", 0.03, 5).unwrap();
        let full = load_splits_full(&dir).unwrap();
        assert_eq!(full.train.x, text.train.x);
        assert_eq!(full.train.y, text.train.y);
        assert_eq!(full.test.x, text.test.x);
        assert_eq!(full.validation.y, text.validation.y);
        // Hashed partition in the header == what the text cluster path uses.
        let h = open_header(&dir).unwrap();
        let p = text.train.p();
        assert_eq!(h.partition.blocks, FeaturePartition::hashed(p, 3, 5).blocks);
        // Per-rank bytes: every block reads strictly less than the full set.
        let total: u64 = (0..3)
            .map(|r| h.load_block(&dir, r).unwrap().1.bytes_read)
            .sum();
        for r in 0..3 {
            let (blk, stats) = h.load_block(&dir, r).unwrap();
            assert!(blk.ncols < p);
            assert!(stats.bytes_read < total);
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn partition_kinds_roundtrip_and_parse() {
        for kind in PartitionKind::ALL {
            assert_eq!(PartitionKind::parse(kind.name()), Some(kind));
            assert_eq!(PartitionKind::from_tag(kind.tag()).unwrap(), kind);
        }
        assert_eq!(PartitionKind::parse("metis"), None);
        assert!(PartitionKind::from_tag(9).is_err());
    }

    /// The clustered kind tag (3) survives the header round trip, and the
    /// header partition is exactly what the seam resolves for the same
    /// (matrix, blocks, seed) — the invariant the text/shards parity tests
    /// build on.
    #[test]
    fn convert_recipe_clustered_header_roundtrip() {
        let dir = tmp_dir("convert-clustered");
        let rep =
            convert_recipe("epsilon_like", 0.03, 5, 3, PartitionKind::Clustered, &dir).unwrap();
        assert_eq!(rep.kind, PartitionKind::Clustered);
        let h = open_header(&dir).unwrap();
        assert_eq!(h.kind, PartitionKind::Clustered);
        let text = crate::harness::load_splits("epsilon_like", 0.03, 5).unwrap();
        let want = PartitionKind::Clustered.resolve(&text.train.to_csc(), 3, 5);
        assert_eq!(h.partition.blocks, want.blocks);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn shard_recipe_strips_prefix() {
        assert_eq!(shard_recipe("shards:/data/eps"), Some("/data/eps"));
        assert_eq!(shard_recipe("epsilon_like"), None);
    }
}
