//! Property-based testing harness (no `proptest` crate in the offline env).
//!
//! Provides seeded random case generation with failure reporting that prints
//! the reproducing seed, plus a lightweight shrink loop for integer-vector
//! inputs. Used by invariant tests across sparse/, solver/ and cluster/.

use crate::sparse::FeaturePartition;
use crate::util::rng::Rng;

/// Run `cases` random trials of `prop`, reporting the seed of the first
/// failing case. `prop` returns `Err(msg)` to signal failure.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Base seed is fixed for reproducibility; per-case seeds derive from it.
    let base = 0x5EED_0000_u64;
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Generate a random sparse vector as (index, value) pairs with indices in
/// [0, dim) and values in [-scale, scale], of length up to max_nnz.
pub fn sparse_vec(rng: &mut Rng, dim: usize, max_nnz: usize, scale: f64) -> Vec<(usize, f64)> {
    let nnz = rng.below(max_nnz.min(dim) + 1);
    let idx = rng.sample_indices(dim, nnz);
    idx.into_iter()
        .map(|i| (i, rng.range_f64(-scale, scale)))
        .collect()
}

/// Generate a random dense vector.
pub fn dense_vec(rng: &mut Rng, dim: usize, scale: f64) -> Vec<f64> {
    (0..dim).map(|_| rng.range_f64(-scale, scale)).collect()
}

/// Assert `fp` is a disjoint, complete, owner-consistent cover of `0..p` —
/// the invariant every `PartitionStrategy` must uphold (Theorem 1 needs
/// nothing more of a layout). Shared by the partition property tests.
pub fn check_is_partition(fp: &FeaturePartition, p: usize) -> Result<(), String> {
    let mut seen = vec![false; p];
    for (m, block) in fp.blocks.iter().enumerate() {
        for w in block.windows(2) {
            if w[0] >= w[1] {
                return Err(format!("block {m} not sorted strictly ascending"));
            }
        }
        for &j in block {
            if j >= p {
                return Err(format!("feature {j} out of range"));
            }
            if seen[j] {
                return Err(format!("feature {j} assigned twice"));
            }
            seen[j] = true;
            if fp.owner[j] != m {
                return Err(format!("owner[{j}] inconsistent"));
            }
        }
    }
    if !seen.iter().all(|&s| s) {
        return Err("not all features assigned".into());
    }
    Ok(())
}

/// Assert two floats are close (absolute + relative tolerance).
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} != {b} (tol {tol}, scaled diff {})", (a - b).abs() / scale))
    }
}

/// Assert all pairs in two slices are close.
pub fn all_close(a: &[f64], b: &[f64], tol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        close(*x, *y, tol).map_err(|e| format!("at index {i}: {e}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("uniform in range", 100, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn check_reports_failure_with_seed() {
        check("always fails", 5, |_| Err("nope".to_string()));
    }

    #[test]
    fn sparse_vec_indices_valid_and_sorted() {
        check("sparse vec valid", 200, |rng| {
            let v = sparse_vec(rng, 50, 20, 3.0);
            for w in v.windows(2) {
                if w[0].0 >= w[1].0 {
                    return Err("indices not strictly increasing".into());
                }
            }
            if v.iter().any(|&(i, _)| i >= 50) {
                return Err("index out of range".into());
            }
            Ok(())
        });
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9).is_ok());
        assert!(close(1.0, 1.1, 1e-9).is_err());
        // relative scaling for large values
        assert!(close(1e12, 1e12 + 1.0, 1e-9).is_ok());
    }
}
