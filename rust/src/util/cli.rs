//! Declarative command-line flag parsing (no `clap` in the offline env).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and generates `--help` text from registered specs.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
}

/// Parsed arguments plus the specs used for help/validation.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

/// Builder-style CLI definition.
pub struct Cli {
    program: String,
    about: String,
    specs: Vec<FlagSpec>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown flag --{0}")]
    UnknownFlag(String),
    #[error("flag --{0} requires a value")]
    MissingValue(String),
    #[error("help requested")]
    HelpRequested,
    #[error("invalid value for --{flag}: {value} ({reason})")]
    InvalidValue {
        flag: String,
        value: String,
        reason: String,
    },
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli {
            program: program.to_string(),
            about: about.to_string(),
            specs: Vec::new(),
        }
    }

    /// Register a value flag with a default.
    pub fn flag(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_bool: false,
        });
        self
    }

    /// Register a required value flag (no default).
    pub fn required(mut self, name: &str, help: &str) -> Self {
        self.specs.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: false,
        });
        self
    }

    /// Register a boolean switch (defaults to false).
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.specs.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some("false".to_string()),
            is_bool: true,
        });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nFlags:\n", self.program, self.about);
        for spec in &self.specs {
            let default = spec
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_else(|| " [required]".to_string());
            s.push_str(&format!("  --{:<24} {}{}\n", spec.name, spec.help, default));
        }
        s
    }

    /// Parse an argv slice (excluding program name).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        // Seed defaults.
        for spec in &self.specs {
            if let Some(d) = &spec.default {
                args.values.insert(spec.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(CliError::HelpRequested);
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| CliError::UnknownFlag(name.clone()))?;
                let value = if spec.is_bool {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| CliError::MissingValue(name.clone()))?
                };
                args.values.insert(name, value);
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        // Check required flags.
        for spec in &self.specs {
            if spec.default.is_none() && !args.values.contains_key(&spec.name) {
                return Err(CliError::MissingValue(spec.name.clone()));
            }
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not registered"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an unsigned integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an unsigned integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be a number"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), "true" | "1" | "yes" | "on")
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .flag("nodes", "8", "number of nodes")
            .flag("l1", "0.5", "l1 penalty")
            .switch("alb", "enable ALB")
            .required("dataset", "dataset name")
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cli()
            .parse(&argv(&["--dataset", "webspam", "--nodes=16"]))
            .unwrap();
        assert_eq!(a.get_usize("nodes"), 16);
        assert_eq!(a.get_f64("l1"), 0.5);
        assert!(!a.get_bool("alb"));
        assert_eq!(a.get("dataset"), "webspam");
    }

    #[test]
    fn boolean_switch() {
        let a = cli()
            .parse(&argv(&["--dataset", "d", "--alb"]))
            .unwrap();
        assert!(a.get_bool("alb"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(matches!(
            cli().parse(&argv(&["--nodes", "2"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(matches!(
            cli().parse(&argv(&["--dataset", "d", "--bogus", "1"])),
            Err(CliError::UnknownFlag(_))
        ));
    }

    #[test]
    fn positional_collected() {
        let a = cli().parse(&argv(&["--dataset", "d", "pos1", "pos2"])).unwrap();
        assert_eq!(a.positional(), &["pos1".to_string(), "pos2".to_string()]);
    }

    #[test]
    fn help_text_lists_flags() {
        let h = cli().help_text();
        assert!(h.contains("--nodes"));
        assert!(h.contains("[default: 8]"));
        assert!(h.contains("[required]"));
    }
}
