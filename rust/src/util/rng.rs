//! Self-contained pseudo-random number generation.
//!
//! The offline build environment ships no `rand` crate, so the generators the
//! experiments need (uniform, normal, Bernoulli, Zipf, shuffling) are
//! implemented here. The core generator is xoshiro256++ seeded via SplitMix64
//! — the same construction `rand_xoshiro` uses — which is more than adequate
//! for synthetic-data generation and randomized testing (not cryptography).

/// SplitMix64 step; used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG (Blackman & Vigna). 2^256-1 period, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Deterministic generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Marsaglia polar method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Exponential with rate `lambda`.
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates on an
    /// index vec for small k relative to n; reservoir otherwise).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx.sort_unstable();
            idx
        } else {
            // Floyd's algorithm.
            let mut chosen = std::collections::BTreeSet::new();
            for j in (n - k)..n {
                let t = self.below(j + 1);
                if !chosen.insert(t) {
                    chosen.insert(j);
                }
            }
            chosen.into_iter().collect()
        }
    }
}

/// Zipf(s) sampler over ranks {0, .., n-1} via inverse-CDF on a precomputed
/// cumulative table. Used for power-law feature popularity in the webspam /
/// clickstream generators.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, exponent: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for r in 1..=n {
            acc += 1.0 / (r as f64).powf(exponent);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Draw a rank in [0, n); rank 0 is the most popular.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|c| c.total_cmp(&u)) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(13);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(19);
        for &(n, k) in &[(100, 5), (100, 80), (10, 10), (1000, 1)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            for w in s.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn zipf_monotone_popularity() {
        let mut r = Rng::new(23);
        let z = Zipf::new(50, 1.1);
        let mut counts = vec![0usize; 50];
        for _ in 0..200_000 {
            counts[z.sample(&mut r)] += 1;
        }
        // Rank 0 must dominate rank 10 which must dominate rank 40.
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[40]);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(29);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}
