//! Std-only scoped thread pool — the execution substrate of the hybrid
//! (intra-rank multi-threaded) CD mode.
//!
//! [`ScopedPool`] owns a fixed set of persistent worker threads and executes
//! *waves* of borrowed jobs: [`ScopedPool::run`] enqueues every job, wakes
//! the workers, and blocks until the whole wave completed. Because the call
//! does not return before the last job finished (panicking jobs included —
//! the completion latch fires either way), jobs may safely borrow from the
//! caller's stack: the borrow provably outlives every use, which is the
//! classic scoped-thread soundness argument. No crates — the offline
//! container builds with std alone (see DESIGN.md §Substitutions).
//!
//! Determinism contract: the pool imposes no ordering of its own. Callers
//! that need scheduling-independent results give each job its own output
//! slot and reduce the slots in index order after `run` returns — the
//! "deterministic ordered reduction" the hybrid CD mode relies on
//! (`HybridCd::wave` + `reduce_into` are that shape).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A queued job with its caller-side lifetime erased. Sound because `run`
/// waits for the wave before returning (see the safety comment there).
type Task = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    queue: VecDeque<Task>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    work: Condvar,
}

/// Completion latch for one wave of jobs.
struct WaveLatch {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl WaveLatch {
    fn new(jobs: usize) -> WaveLatch {
        WaveLatch {
            remaining: Mutex::new(jobs),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        }
    }

    fn job_done(&self) {
        let mut left = self.remaining.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut left = self.remaining.lock().unwrap();
        while *left > 0 {
            left = self.done.wait(left).unwrap();
        }
    }
}

/// Fixed-size pool of persistent worker threads executing scoped job waves.
pub struct ScopedPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
}

impl ScopedPool {
    /// Spawn a pool of `threads.max(1)` persistent workers.
    pub fn new(threads: usize) -> ScopedPool {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                queue: VecDeque::new(),
                shutdown: false,
            }),
            work: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|k| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("cd-pool-{k}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn pool worker")
            })
            .collect();
        ScopedPool { shared, workers }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Run one wave of jobs to completion. Blocks until every job ran, then
    /// re-panics here if any job panicked (the workers themselves survive a
    /// job panic and keep serving later waves). Must not be called from
    /// inside a pool job — the wave would wait on a worker slot it occupies.
    pub fn run<'scope>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if jobs.is_empty() {
            return;
        }
        let latch = Arc::new(WaveLatch::new(jobs.len()));
        {
            let mut st = self.shared.state.lock().unwrap();
            for job in jobs {
                // SAFETY: erases 'scope to 'static. `latch.wait()` below
                // blocks until this job finished executing (`job_done` runs
                // whether the job returned or panicked), so every borrow
                // captured by the job strictly outlives its use.
                let job: Task = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Task>(job)
                };
                let l = Arc::clone(&latch);
                st.queue.push_back(Box::new(move || {
                    if catch_unwind(AssertUnwindSafe(job)).is_err() {
                        l.panicked.store(true, Ordering::SeqCst);
                    }
                    l.job_done();
                }));
            }
        }
        self.shared.work.notify_all();
        latch.wait();
        if latch.panicked.load(Ordering::SeqCst) {
            panic!("a pool job panicked (wave completed before propagating)");
        }
    }

}

impl Drop for ScopedPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: &PoolShared) {
    loop {
        let task = {
            let mut st = sh.state.lock().unwrap();
            loop {
                if let Some(t) = st.queue.pop_front() {
                    break t;
                }
                if st.shutdown {
                    return;
                }
                st = sh.work.wait(st).unwrap();
            }
        };
        task();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    /// Run `f(k)` for `k ∈ 0..n` with one output slot per job, returning
    /// the slots in index order — the ordered-reduction shape every
    /// determinism test below leans on.
    fn run_indexed<R, F>(pool: &ScopedPool, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let f = &f;
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = slots
            .iter_mut()
            .enumerate()
            .map(|(k, slot)| {
                Box::new(move || *slot = Some(f(k))) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run(jobs);
        slots
            .into_iter()
            .map(|s| s.expect("pool job filled its slot"))
            .collect()
    }

    #[test]
    fn empty_wave_returns_immediately() {
        let pool = ScopedPool::new(2);
        pool.run(Vec::new());
        assert_eq!(pool.threads(), 2);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let pool = ScopedPool::new(0);
        assert_eq!(pool.threads(), 1);
        assert_eq!(run_indexed(&pool, 3, |k| k * 2), vec![0, 2, 4]);
    }

    #[test]
    fn slotted_results_are_in_index_order() {
        // Later jobs finish first (earlier ones sleep longer): the output
        // must still come back in index order.
        let pool = ScopedPool::new(4);
        let got = run_indexed(&pool, 8, |k| {
            std::thread::sleep(Duration::from_millis(((8 - k) * 3) as u64));
            k
        });
        assert_eq!(got, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn jobs_may_borrow_and_mutate_disjoint_caller_state() {
        let pool = ScopedPool::new(3);
        let input = vec![1.0f64, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut out = vec![0.0f64; 6];
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
                .iter_mut()
                .zip(input.iter())
                .map(|(slot, v)| {
                    Box::new(move || *slot = v * v) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run(jobs);
        }
        assert_eq!(out, vec![1.0, 4.0, 9.0, 16.0, 25.0, 36.0]);
    }

    #[test]
    fn pool_is_reusable_across_many_waves() {
        let pool = ScopedPool::new(2);
        let counter = AtomicUsize::new(0);
        for _ in 0..50 {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|_| {
                    let c = &counter;
                    Box::new(move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run(jobs);
        }
        assert_eq!(counter.load(Ordering::SeqCst), 200);
    }

    #[test]
    fn job_panic_propagates_and_pool_survives() {
        let pool = ScopedPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| panic!("boom")),
                Box::new(|| {}),
            ];
            pool.run(jobs);
        }));
        assert!(caught.is_err(), "wave with a panicking job must panic");
        // The workers survived the panic: the next wave still completes.
        assert_eq!(run_indexed(&pool, 4, |k| k + 1), vec![1, 2, 3, 4]);
    }

    #[test]
    fn more_jobs_than_workers_all_complete() {
        let pool = ScopedPool::new(2);
        let got = run_indexed(&pool, 37, |k| k as u64 * 3);
        assert_eq!(got.len(), 37);
        for (k, v) in got.iter().enumerate() {
            assert_eq!(*v, k as u64 * 3);
        }
    }
}
