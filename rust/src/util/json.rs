//! Minimal JSON value model + emitter for metric logs and bench reports.
//!
//! No `serde` is available offline; the experiments only need to *write*
//! well-formed JSON (trace files consumed by plotting scripts), plus parse
//! the flat config files, so this stays intentionally small.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are f64 (sufficient for metrics).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if self is not an object.
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse a JSON document (recursive descent). Numbers parse as f64; no
/// unicode-escape support beyond \uXXXX for the BMP (enough for manifests).
pub fn parse(src: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: src.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).ok_or("bad \\u codepoint")?);
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_shapes() {
        let mut o = Json::obj();
        o.set("name", "d-glmnet")
            .set("iters", 42usize)
            .set("loss", 0.125f64)
            .set("ok", true)
            .set("series", vec![1.0, 2.5, 3.0]);
        let s = o.dump();
        assert_eq!(
            s,
            r#"{"iters":42,"loss":0.125,"name":"d-glmnet","ok":true,"series":[1,2.5,3]}"#
        );
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.dump(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
    }

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": null}, "e": true}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap(), &Json::from(vec![1.0, 2.5, -300.0]));
        assert_eq!(
            v.get("b").unwrap().get("c").unwrap().as_str(),
            Some("x\ny")
        );
        assert_eq!(v.get("e").unwrap(), &Json::Bool(true));
        // dump -> parse -> same
        let again = parse(&v.dump()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1} trailing").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn parse_unicode_escape() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn parse_real_manifest_shape() {
        let src = r#"{"k_alphas": 64, "tile": 1024, "artifacts": [
            {"file": "stats_logistic_1024.hlo.txt", "model": "stats", "kind": "logistic", "block": 1024}]}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("k_alphas").unwrap().as_f64(), Some(64.0));
        if let Json::Arr(arts) = v.get("artifacts").unwrap() {
            assert_eq!(arts[0].get("block").unwrap().as_f64(), Some(1024.0));
        } else {
            panic!("artifacts not an array");
        }
    }
}
