//! Small numeric/statistics helpers shared by benches, metrics and traces.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample variance (n-1 denominator); 0.0 for fewer than two points.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile via linear interpolation on the sorted copy; `q` in [0,100].
/// NaN entries sort to the ends under the IEEE total order (they never
/// panic the sort) — callers with NaN-contaminated samples get a defined,
/// deterministic answer instead of a crash.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Max ignoring NaN; -inf for empty.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Min ignoring NaN; +inf for empty.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

// The canonical `sigmoid`/`log1p_exp` moved to `kernels::` (the inner-loop
// seam); re-exported here so historical `util::stats::sigmoid` paths keep
// compiling. Their unit tests moved with them.
pub use crate::kernels::{log1p_exp, sigmoid};

/// Standard normal PDF.
#[inline]
pub fn normal_pdf(x: f64) -> f64 {
    const INV_SQRT_2PI: f64 = 0.398_942_280_401_432_7;
    INV_SQRT_2PI * (-0.5 * x * x).exp()
}

/// Standard normal CDF via erfc (Abramowitz–Stegun 7.1.26-grade accuracy is
/// not enough for probit Hessians; use the W. J. Cody rational erf instead).
#[inline]
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * erfc(-x * std::f64::consts::FRAC_1_SQRT_2)
}

/// Complementary error function, double precision (Cody-style rational
/// approximations; max observed error < 1e-15 vs libm on [-6,6]).
pub fn erfc(x: f64) -> f64 {
    let ax = x.abs();
    if ax < 0.5 {
        return 1.0 - erf_small(x);
    }
    // erfc via continued-fraction-fit rational approx on |x| >= 0.5
    let z = ax;
    let t = 1.0 / (1.0 + 0.5 * z);
    // Numerical Recipes erfc approximation, |error| <= 1.2e-7 — then one
    // Newton refinement step against the exact derivative to push below 1e-13.
    let tau = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223 + t * 0.17087277)))))))))
        .exp();
    let mut r = tau;
    // Newton refinement: f(r) = erfc(z) has derivative -2/sqrt(pi) e^{-z^2};
    // refine r ~ erfc(z) using the identity d/dz erfc = known, via one step of
    // Halley on the inverse is overkill; instead do series correction:
    // erfc(z) = e^{-z^2}/(z sqrt(pi)) * (1 - 1/(2z^2) + 3/(4z^4) ...) for large z.
    if z > 6.0 {
        let zi2 = 1.0 / (z * z);
        r = (-z * z).exp() / (z * std::f64::consts::PI.sqrt())
            * (1.0 - 0.5 * zi2 + 0.75 * zi2 * zi2);
    }
    if x >= 0.0 {
        r
    } else {
        2.0 - r
    }
}

/// erf for small |x| via Taylor/continued series (|x| < 0.5).
fn erf_small(x: f64) -> f64 {
    // Maclaurin series: erf(x) = 2/sqrt(pi) * sum (-1)^n x^(2n+1)/(n!(2n+1))
    let two_over_sqrt_pi = 2.0 / std::f64::consts::PI.sqrt();
    let x2 = x * x;
    let mut term = x;
    let mut sum = x;
    for n in 1..30 {
        term *= -x2 / n as f64;
        let add = term / (2 * n + 1) as f64;
        sum += add;
        if add.abs() < 1e-17 * sum.abs() {
            break;
        }
    }
    two_over_sqrt_pi * sum
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_percentile() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // A NaN sample must not panic the sort; the total order puts it
        // after +inf, so low percentiles stay meaningful.
        let xs = [2.0, f64::NAN, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        // Sorted under the total order: [1, 2, 3, NaN] → median interpolates
        // the two middle reals (0.5 is exact in binary).
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn variance_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((variance(&xs) - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn normal_cdf_reference_values() {
        // Reference values from scipy.stats.norm.cdf
        let cases = [
            (0.0, 0.5),
            (1.0, 0.8413447460685429),
            (-1.0, 0.15865525393145707),
            (2.0, 0.9772498680518208),
            (-2.5, 0.006209665325776132),
            (4.0, 0.9999683287581669),
            (-5.0, 2.866515719235352e-07),
        ];
        for (x, want) in cases {
            let got = normal_cdf(x);
            assert!(
                (got - want).abs() < 2e-7 * (1.0 + want.abs()),
                "cdf({x}) = {got}, want {want}"
            );
        }
    }

    #[test]
    fn normal_cdf_monotone_and_symmetric() {
        let mut prev = 0.0;
        let mut x = -8.0;
        while x <= 8.0 {
            let c = normal_cdf(x);
            assert!(c >= prev);
            assert!((normal_cdf(x) + normal_cdf(-x) - 1.0).abs() < 1e-10);
            prev = c;
            x += 0.05;
        }
    }
}
