//! Environment substrates: PRNG, CLI, JSON, property testing, benchmarking,
//! numeric helpers. These replace crates unavailable in the offline build
//! (`rand`, `clap`, `serde`, `proptest`, `criterion`) — see DESIGN.md.

pub mod bench;
pub mod cli;
pub mod cputime;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
