//! Per-thread CPU time — the measurement basis of the virtual cluster clock.
//!
//! The simulation host may have fewer cores than simulated nodes (this box
//! has one), so wall-clock time under thread oversubscription says nothing
//! about the parallel algorithm. CLOCK_THREAD_CPUTIME_ID counts only the
//! cycles this thread actually executed, which is exactly the per-node
//! compute cost an M-node cluster would see; the coordinator maxes it over
//! nodes per iteration and adds the modeled wire time (DESIGN.md
//! §Substitutions).

/// CPU seconds consumed by the calling thread.
pub fn thread_cpu_secs() -> f64 {
    let mut ts = libc::timespec {
        tv_sec: 0,
        tv_nsec: 0,
    };
    let rc = unsafe { libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    debug_assert_eq!(rc, 0);
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_and_advances_under_load() {
        let t0 = thread_cpu_secs();
        // Busy work.
        let mut acc = 0u64;
        for i in 0..5_000_000u64 {
            acc = acc.wrapping_add(i * i);
        }
        std::hint::black_box(acc);
        let t1 = thread_cpu_secs();
        assert!(t1 > t0, "cpu clock did not advance");
    }

    #[test]
    fn sleep_consumes_no_cpu_time() {
        let t0 = thread_cpu_secs();
        std::thread::sleep(std::time::Duration::from_millis(50));
        let t1 = thread_cpu_secs();
        assert!(t1 - t0 < 0.02, "sleep burned {:.3}s CPU", t1 - t0);
    }

    #[test]
    fn other_threads_do_not_count() {
        let h = std::thread::spawn(|| {
            let mut acc = 0u64;
            for i in 0..20_000_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
        });
        let t0 = thread_cpu_secs();
        h.join().unwrap();
        let t1 = thread_cpu_secs();
        assert!(t1 - t0 < 0.05, "other thread's work leaked in: {:.3}s", t1 - t0);
    }
}
