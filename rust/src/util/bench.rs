//! Measurement harness for `harness = false` benches (no `criterion` in the
//! offline env). Provides warmup + sampled timing with median/p95 reporting,
//! and a table printer for paper-style output rows.

use std::time::{Duration, Instant};

use crate::util::stats;

/// Timing summary over the collected samples (seconds).
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub samples: Vec<f64>,
}

impl Summary {
    pub fn median(&self) -> f64 {
        stats::median(&self.samples)
    }
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples)
    }
    pub fn p95(&self) -> f64 {
        stats::percentile(&self.samples, 95.0)
    }
    pub fn min(&self) -> f64 {
        stats::min(&self.samples)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} median {:>10}  mean {:>10}  p95 {:>10}  min {:>10}  (n={})",
            self.name,
            fmt_dur(self.median()),
            fmt_dur(self.mean()),
            fmt_dur(self.p95()),
            fmt_dur(self.min()),
            self.samples.len(),
        )
    }
}

/// Human format for a duration in seconds.
pub fn fmt_dur(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.3}s", secs)
    }
}

/// Run `f` with `warmup` unrecorded calls then `samples` timed calls.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let s = Summary {
        name: name.to_string(),
        samples: times,
    };
    crate::obs::log::emit(&s.report());
    s
}

/// Run `f` repeatedly for at least `budget`, at least 3 samples.
pub fn bench_for<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> Summary {
    // One calibration call.
    let t0 = Instant::now();
    f();
    let first = t0.elapsed().as_secs_f64();
    let mut times = vec![first];
    let deadline = Instant::now() + budget;
    while Instant::now() < deadline || times.len() < 3 {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
        if times.len() > 10_000 {
            break;
        }
    }
    let s = Summary {
        name: name.to_string(),
        samples: times,
    };
    crate::obs::log::emit(&s.report());
    s
}

/// Append one record to a JSON-array trajectory file (the `BENCH_*.json`
/// files at the repo root), creating it on first use. Each bench run pushes
/// one timestamped object so the numbers accumulate into a trajectory
/// across commits. A malformed existing file is replaced rather than
/// crashing the bench.
pub fn append_json_record(path: &std::path::Path, fill: impl FnOnce(&mut crate::util::json::Json)) {
    use crate::util::json::{self, Json};
    let mut records = match std::fs::read_to_string(path)
        .ok()
        .and_then(|text| json::parse(&text).ok())
    {
        Some(Json::Arr(items)) => items,
        _ => Vec::new(),
    };
    let mut rec = Json::obj();
    fill(&mut rec);
    records.push(rec);
    match std::fs::write(path, Json::Arr(records).dump()) {
        Ok(()) => crate::obs::log::emit(&format!("appended record to {}", path.display())),
        Err(e) => crate::obs::log::emit(&format!("could not write {}: {e}", path.display())),
    }
}

/// Paper-style fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// The aligned table as a string (one trailing newline) — used where
    /// the table is embedded in a larger report (`obs::runlog::report`).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        let rendered = self.render();
        crate::obs::log::emit(rendered.trim_end_matches('\n'));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let s = bench("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.samples.len(), 5);
        assert!(s.median() >= 0.0);
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(5e-9).ends_with("ns"));
        assert!(fmt_dur(5e-6).ends_with("µs"));
        assert!(fmt_dur(5e-3).ends_with("ms"));
        assert!(fmt_dur(5.0).ends_with('s'));
    }

    #[test]
    fn table_prints_aligned() {
        let mut t = Table::new(&["dataset", "n"]);
        t.row(&["webspam_like".to_string(), "30000".to_string()]);
        let r = t.render();
        assert!(r.contains("| dataset      | n     |"), "{r}");
        assert!(r.contains("| webspam_like | 30000 |"), "{r}");
        assert_eq!(r.lines().count(), 3);
        t.print(); // smoke: no panic
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".to_string()]);
    }

    #[test]
    fn append_json_record_accumulates_and_heals() {
        use crate::util::json::{self, Json};
        let path = std::env::temp_dir().join(format!(
            "dglmnet_bench_append_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        append_json_record(&path, |r| {
            r.set("k", 1.0);
        });
        append_json_record(&path, |r| {
            r.set("k", 2.0);
        });
        let text = std::fs::read_to_string(&path).unwrap();
        match json::parse(&text).unwrap() {
            Json::Arr(items) => assert_eq!(items.len(), 2),
            other => panic!("expected array, got {other:?}"),
        }
        // A malformed trajectory is replaced, not a crash.
        std::fs::write(&path, "not json").unwrap();
        append_json_record(&path, |r| {
            r.set("k", 3.0);
        });
        let text = std::fs::read_to_string(&path).unwrap();
        match json::parse(&text).unwrap() {
            Json::Arr(items) => assert_eq!(items.len(), 1),
            other => panic!("expected array, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }
}
