//! # d-GLMNET — distributed coordinate descent for regularized GLMs
//!
//! Reproduction of Trofimov & Genkin (2016), "Distributed Coordinate Descent
//! for Generalized Linear Models with Regularization", as a three-layer
//! Rust + JAX/Pallas system:
//!
//! - **L3** (this crate): the coordination contribution — feature-sharded
//!   workers, block coordinate descent, AllReduce of `XΔβ`, global line
//!   search, adaptive trust-region `μ`, and Asynchronous Load Balancing —
//!   plus the paper's baselines (ADMM with sharing, online truncated
//!   gradient, L-BFGS) and a simulated cluster substrate.
//! - **L2/L1** (python/, build-time only): GLM per-example statistics and
//!   batched line-search objectives as JAX graphs wrapping Pallas kernels,
//!   AOT-lowered to HLO text in `artifacts/`.
//! - **runtime**: PJRT CPU client that loads and executes the artifacts from
//!   the Rust hot path — Python is never on the request path.
//!
//! See DESIGN.md for the system inventory and experiment index, and
//! EXPERIMENTS.md for measured results.

pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod solver;
pub mod glm;
pub mod harness;
pub mod metrics;
pub mod runtime;
pub mod sparse;
pub mod util;
