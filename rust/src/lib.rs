//! # d-GLMNET — distributed coordinate descent for regularized GLMs
//!
//! Reproduction of Trofimov & Genkin (2016), "Distributed Coordinate Descent
//! for Generalized Linear Models with Regularization", as a three-layer
//! Rust + JAX/Pallas system:
//!
//! - **L3** (this crate): the coordination contribution — feature-sharded
//!   workers, block coordinate descent, AllReduce of `XΔβ`, global line
//!   search, adaptive trust-region `μ`, and Asynchronous Load Balancing —
//!   plus the paper's baselines (ADMM with sharing, online truncated
//!   gradient, L-BFGS) and a simulated cluster substrate.
//! - **L2/L1** (python/, build-time only): GLM per-example statistics and
//!   batched line-search objectives as JAX graphs wrapping Pallas kernels,
//!   AOT-lowered to HLO text in `artifacts/`.
//! - **runtime**: PJRT CPU client that loads and executes the artifacts from
//!   the Rust hot path — Python is never on the request path.
//! - **serve**: the online path — a versioned model registry with lock-free
//!   hot-swap, a micro-batched scoring engine behind the same compute seam,
//!   and a newline-delimited-JSON TCP endpoint (`dglmnet serve`), so a model
//!   trained with `train --save-model` can be promoted and scored against
//!   live traffic without a restart.
//! - **obs**: cluster-wide observability — structured leveled logging, span
//!   tracing of every outer iteration's phases, a counters/gauges/histogram
//!   registry, and the merged run-log pipeline behind `train --trace-out` /
//!   `dglmnet trace-report` (import [`obs::prelude`] for the whole kit).
//!
//! See DESIGN.md for the system inventory and experiment index.

pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod solver;
pub mod glm;
pub mod harness;
pub mod kernels;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod util;

pub use obs::prelude;
