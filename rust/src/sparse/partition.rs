//! Data partitioning across computational nodes.
//!
//! The paper shards features pseudo-randomly: the Map/Reduce repartition
//! assigns feature j to node hash(j) mod M (Reduce-by-key). `FeaturePartition`
//! reproduces that layout and also offers a balanced variant that equalizes
//! per-node nnz (useful for the ALB ablation: hash splitting is what makes
//! stragglers appear in the first place).
//!
//! `ExamplePartition` is the "horizontal" split used by the online-learning
//! and L-BFGS baselines (Agarwal et al. 2014).

use crate::sparse::csc::Csc;
use crate::sparse::csr::Csr;

/// Assignment of features to M nodes: S^1 ∪ ... ∪ S^M = {0..p}, disjoint.
#[derive(Clone, Debug)]
pub struct FeaturePartition {
    /// blocks[m] = sorted global feature ids owned by node m (S^m).
    pub blocks: Vec<Vec<usize>>,
    /// owner[j] = node owning feature j.
    pub owner: Vec<usize>,
}

/// 64-bit finalizer hash (same family as SplitMix64's mixer); deterministic
/// stand-in for the Reduce-by-key hash in the paper's repartition job.
#[inline]
pub fn hash64(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

impl FeaturePartition {
    /// Pseudo-random hash partition (the paper's layout).
    pub fn hashed(p: usize, m: usize, seed: u64) -> FeaturePartition {
        assert!(m > 0);
        let mut blocks = vec![Vec::new(); m];
        let mut owner = Vec::with_capacity(p);
        for j in 0..p {
            let node = (hash64(j as u64 ^ seed) % m as u64) as usize;
            blocks[node].push(j);
            owner.push(node);
        }
        FeaturePartition { blocks, owner }
    }

    /// Contiguous partition (for tests / worst-case correlation layout).
    pub fn contiguous(p: usize, m: usize) -> FeaturePartition {
        assert!(m > 0);
        let mut blocks = vec![Vec::new(); m];
        let mut owner = Vec::with_capacity(p);
        let chunk = p.div_ceil(m);
        for j in 0..p {
            let node = (j / chunk).min(m - 1);
            blocks[node].push(j);
            owner.push(node);
        }
        FeaturePartition { blocks, owner }
    }

    /// Greedy nnz-balanced partition: features sorted by column nnz
    /// descending, each assigned to the currently lightest node (LPT
    /// scheduling). Minimizes per-iteration compute skew.
    pub fn nnz_balanced(x: &Csc, m: usize) -> FeaturePartition {
        assert!(m > 0);
        let p = x.ncols;
        let mut order: Vec<usize> = (0..p).collect();
        order.sort_unstable_by_key(|&j| std::cmp::Reverse(x.col_nnz(j)));
        let mut load = vec![0usize; m];
        let mut blocks = vec![Vec::new(); m];
        let mut owner = vec![0usize; p];
        for j in order {
            let node = (0..m).min_by_key(|&k| load[k]).unwrap();
            load[node] += x.col_nnz(j).max(1);
            blocks[node].push(j);
            owner[j] = node;
        }
        for b in blocks.iter_mut() {
            b.sort_unstable();
        }
        FeaturePartition { blocks, owner }
    }

    pub fn num_nodes(&self) -> usize {
        self.blocks.len()
    }

    pub fn num_features(&self) -> usize {
        self.owner.len()
    }

    /// Materialize node m's column block X^m from the global matrix.
    pub fn shard(&self, x: &Csc, m: usize) -> Csc {
        x.select_cols(&self.blocks[m])
    }

    /// Per-node nnz loads (skew diagnostics; drives slow-node experiments).
    pub fn nnz_loads(&self, x: &Csc) -> Vec<usize> {
        self.blocks
            .iter()
            .map(|b| b.iter().map(|&j| x.col_nnz(j)).sum())
            .collect()
    }

    /// max/mean nnz load ratio — 1.0 is perfectly balanced.
    pub fn skew(&self, x: &Csc) -> f64 {
        let loads = self.nnz_loads(x);
        let max = *loads.iter().max().unwrap_or(&0) as f64;
        let mean = loads.iter().sum::<usize>() as f64 / loads.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Scatter a concatenation of per-block weight vectors back to global
    /// feature order. `block_weights[m]` is indexed like `blocks[m]`.
    pub fn unshard_weights(&self, block_weights: &[Vec<f64>]) -> Vec<f64> {
        let mut beta = vec![0.0; self.num_features()];
        for (m, block) in self.blocks.iter().enumerate() {
            assert_eq!(block.len(), block_weights[m].len());
            for (local, &j) in block.iter().enumerate() {
                beta[j] = block_weights[m][local];
            }
        }
        beta
    }
}

/// Assignment of examples to M nodes (round-robin or hashed).
#[derive(Clone, Debug)]
pub struct ExamplePartition {
    pub blocks: Vec<Vec<usize>>,
}

impl ExamplePartition {
    pub fn round_robin(n: usize, m: usize) -> ExamplePartition {
        assert!(m > 0);
        let mut blocks = vec![Vec::new(); m];
        for i in 0..n {
            blocks[i % m].push(i);
        }
        ExamplePartition { blocks }
    }

    pub fn hashed(n: usize, m: usize, seed: u64) -> ExamplePartition {
        assert!(m > 0);
        let mut blocks = vec![Vec::new(); m];
        for i in 0..n {
            blocks[(hash64(i as u64 ^ seed) % m as u64) as usize].push(i);
        }
        ExamplePartition { blocks }
    }

    pub fn shard(&self, x: &Csr, m: usize) -> Csr {
        x.select_rows(&self.blocks[m])
    }

    pub fn shard_labels(&self, y: &[f64], m: usize) -> Vec<f64> {
        self.blocks[m].iter().map(|&i| y[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn check_is_partition(fp: &FeaturePartition, p: usize) -> Result<(), String> {
        let mut seen = vec![false; p];
        for (m, block) in fp.blocks.iter().enumerate() {
            for &j in block {
                if j >= p {
                    return Err(format!("feature {j} out of range"));
                }
                if seen[j] {
                    return Err(format!("feature {j} assigned twice"));
                }
                seen[j] = true;
                if fp.owner[j] != m {
                    return Err(format!("owner[{j}] inconsistent"));
                }
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("not all features assigned".into());
        }
        Ok(())
    }

    #[test]
    fn prop_hashed_is_partition() {
        prop::check("hashed partition disjoint+complete", 50, |rng| {
            let p = 1 + rng.below(200);
            let m = 1 + rng.below(16);
            let fp = FeaturePartition::hashed(p, m, rng.next_u64());
            check_is_partition(&fp, p)
        });
    }

    #[test]
    fn prop_contiguous_is_partition() {
        prop::check("contiguous partition disjoint+complete", 50, |rng| {
            let p = 1 + rng.below(200);
            let m = 1 + rng.below(16);
            check_is_partition(&FeaturePartition::contiguous(p, m), p)
        });
    }

    #[test]
    fn hashed_deterministic_per_seed() {
        let a = FeaturePartition::hashed(100, 4, 7);
        let b = FeaturePartition::hashed(100, 4, 7);
        assert_eq!(a.owner, b.owner);
        let c = FeaturePartition::hashed(100, 4, 8);
        assert_ne!(a.owner, c.owner);
    }

    #[test]
    fn hashed_roughly_balanced() {
        let fp = FeaturePartition::hashed(10_000, 8, 1);
        for b in &fp.blocks {
            let frac = b.len() as f64 / 10_000.0;
            assert!((frac - 0.125).abs() < 0.02, "block frac {frac}");
        }
    }

    #[test]
    fn nnz_balanced_beats_hash_on_skewed_data() {
        // Power-law columns: column j has ~1000/(j+1) entries.
        let mut trips = Vec::new();
        for j in 0..50usize {
            let cnt = (1000 / (j + 1)).max(1);
            for i in 0..cnt {
                trips.push((i % 500, j, 1.0));
            }
        }
        let x = Csc::from_triplets(500, 50, trips);
        let hash_skew = FeaturePartition::hashed(50, 4, 3).skew(&x);
        let bal_skew = FeaturePartition::nnz_balanced(&x, 4).skew(&x);
        assert!(
            bal_skew <= hash_skew + 1e-9,
            "balanced {bal_skew} vs hashed {hash_skew}"
        );
        assert!(bal_skew < 1.2, "balanced skew too high: {bal_skew}");
    }

    #[test]
    fn shard_and_unshard_roundtrip() {
        let x = Csc::from_triplets(
            4,
            6,
            vec![
                (0, 0, 1.0),
                (1, 1, 2.0),
                (2, 2, 3.0),
                (3, 3, 4.0),
                (0, 4, 5.0),
                (1, 5, 6.0),
            ],
        );
        let fp = FeaturePartition::hashed(6, 3, 42);
        // per-block weights = global feature id as value
        let block_weights: Vec<Vec<f64>> = fp
            .blocks
            .iter()
            .map(|b| b.iter().map(|&j| j as f64).collect())
            .collect();
        let beta = fp.unshard_weights(&block_weights);
        assert_eq!(beta, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        // shard column count matches block size
        for m in 0..3 {
            assert_eq!(fp.shard(&x, m).ncols, fp.blocks[m].len());
        }
    }

    #[test]
    fn example_partition_covers_all() {
        for m in [1, 3, 8] {
            let ep = ExamplePartition::round_robin(100, m);
            let total: usize = ep.blocks.iter().map(|b| b.len()).sum();
            assert_eq!(total, 100);
            let mut all: Vec<usize> = ep.blocks.concat();
            all.sort_unstable();
            assert_eq!(all, (0..100).collect::<Vec<_>>());
        }
    }

    #[test]
    fn example_shard_labels_align() {
        let x = Csr::from_rows(
            2,
            &[
                vec![(0, 1.0)],
                vec![(1, 2.0)],
                vec![(0, 3.0)],
                vec![(1, 4.0)],
            ],
        );
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let ep = ExamplePartition::round_robin(4, 2);
        let s0 = ep.shard(&x, 0);
        let y0 = ep.shard_labels(&y, 0);
        assert_eq!(s0.nrows, 2);
        assert_eq!(y0, vec![1.0, 1.0]);
    }
}
