//! Data partitioning across computational nodes.
//!
//! The paper shards features pseudo-randomly: the Map/Reduce repartition
//! assigns feature j to node hash(j) mod M (Reduce-by-key). `FeaturePartition`
//! reproduces that layout and also offers a balanced variant that equalizes
//! per-node nnz (useful for the ALB ablation: hash splitting is what makes
//! stragglers appear in the first place) and a correlation-aware variant
//! that clusters features by column co-occurrence (Scherrer et al. 2012:
//! block CD converges in fewer iterations when correlated features share a
//! block, because the per-block quadratic models then capture the coupling
//! the merge step would otherwise fight over).
//!
//! `PartitionStrategy` is the single seam every run mode resolves a layout
//! through — the CLI, the job spec, the shard-header kind tag, and the
//! in-process drivers all name one of its variants instead of improvising a
//! `FeaturePartition::hashed` call.
//!
//! `ExamplePartition` is the "horizontal" split used by the online-learning
//! and L-BFGS baselines (Agarwal et al. 2014).

use anyhow::{bail, Result};

use crate::sparse::csc::Csc;
use crate::sparse::csr::Csr;
use crate::util::rng::Rng;

/// Named feature→block layout, resolved into a concrete `FeaturePartition`
/// in exactly one place per run mode via [`PartitionStrategy::resolve`].
/// The discriminant doubles as the shard-header kind tag (wire-stable:
/// never renumber, only append).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// `hash(j) mod M` — the paper's layout and the default everywhere.
    #[default]
    Hashed,
    /// Contiguous index ranges (locality / worst-case correlation layout).
    Contiguous,
    /// nnz-balanced (LPT) blocks — equalizes per-iteration compute.
    NnzBalanced,
    /// Column co-occurrence clustering with an nnz-balance cap — groups
    /// correlated features so fewer CD couplings cross block boundaries.
    Clustered,
}

impl PartitionStrategy {
    /// Every strategy, for exhaustive property tests.
    pub const ALL: [PartitionStrategy; 4] = [
        PartitionStrategy::Hashed,
        PartitionStrategy::Contiguous,
        PartitionStrategy::NnzBalanced,
        PartitionStrategy::Clustered,
    ];

    /// The CLI spelling: `--partition hashed|contiguous|nnz|cluster`.
    pub fn parse(s: &str) -> Option<PartitionStrategy> {
        match s {
            "hashed" => Some(PartitionStrategy::Hashed),
            "contiguous" => Some(PartitionStrategy::Contiguous),
            "nnz" => Some(PartitionStrategy::NnzBalanced),
            "cluster" => Some(PartitionStrategy::Clustered),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PartitionStrategy::Hashed => "hashed",
            PartitionStrategy::Contiguous => "contiguous",
            PartitionStrategy::NnzBalanced => "nnz",
            PartitionStrategy::Clustered => "cluster",
        }
    }

    /// Shard-header / wire tag. Append-only: existing directories on disk
    /// name these numbers forever.
    pub fn tag(self) -> u64 {
        match self {
            PartitionStrategy::Hashed => 0,
            PartitionStrategy::Contiguous => 1,
            PartitionStrategy::NnzBalanced => 2,
            PartitionStrategy::Clustered => 3,
        }
    }

    pub fn from_tag(t: u64) -> Result<PartitionStrategy> {
        match t {
            0 => Ok(PartitionStrategy::Hashed),
            1 => Ok(PartitionStrategy::Contiguous),
            2 => Ok(PartitionStrategy::NnzBalanced),
            3 => Ok(PartitionStrategy::Clustered),
            _ => bail!("shard header names unknown partition kind tag {t}"),
        }
    }

    /// Whether resolving needs the column structure (`nnz`, `cluster`) or
    /// only the dimensions (`hashed`, `contiguous`). Gate for callers that
    /// would otherwise have to materialize a matrix they don't hold (the
    /// checkpoint-recovery re-shard).
    pub fn needs_matrix(self) -> bool {
        matches!(
            self,
            PartitionStrategy::NnzBalanced | PartitionStrategy::Clustered
        )
    }

    /// Resolve a structure-free strategy from dimensions alone; `None` for
    /// data-dependent strategies (use [`resolve`](Self::resolve)).
    pub fn resolve_dims(self, p: usize, m: usize, seed: u64) -> Option<FeaturePartition> {
        match self {
            PartitionStrategy::Hashed => Some(FeaturePartition::hashed(p, m, seed)),
            PartitionStrategy::Contiguous => Some(FeaturePartition::contiguous(p, m)),
            _ => None,
        }
    }

    /// THE seam: turn the named strategy into a concrete partition of the
    /// matrix's columns. Deterministic in (x, m, seed) for every variant.
    pub fn resolve(self, x: &Csc, m: usize, seed: u64) -> FeaturePartition {
        match self {
            PartitionStrategy::Hashed => FeaturePartition::hashed(x.ncols, m, seed),
            PartitionStrategy::Contiguous => FeaturePartition::contiguous(x.ncols, m),
            PartitionStrategy::NnzBalanced => FeaturePartition::nnz_balanced(x, m),
            PartitionStrategy::Clustered => FeaturePartition::cooccurrence_clustered(x, m, seed),
        }
    }
}

/// Assignment of features to M nodes: S^1 ∪ ... ∪ S^M = {0..p}, disjoint.
#[derive(Clone, Debug)]
pub struct FeaturePartition {
    /// blocks[m] = sorted global feature ids owned by node m (S^m).
    pub blocks: Vec<Vec<usize>>,
    /// owner[j] = node owning feature j.
    pub owner: Vec<usize>,
}

/// 64-bit finalizer hash (same family as SplitMix64's mixer); deterministic
/// stand-in for the Reduce-by-key hash in the paper's repartition job.
#[inline]
pub fn hash64(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// Rows examined by the co-occurrence clusterer and the cut diagnostic: a
/// seeded sample of up to `COOCCURRENCE_SAMPLE_ROWS` rows (sorted, distinct),
/// so both stay O(sample·nnz/n) on tall matrices and agree on what they saw.
pub const COOCCURRENCE_SAMPLE_ROWS: usize = 512;

fn sample_rows(n: usize, seed: u64) -> Vec<usize> {
    if n <= COOCCURRENCE_SAMPLE_ROWS {
        return (0..n).collect();
    }
    // Domain-separated from the corpus/partition seeds sharing the run seed.
    let mut rng = Rng::new(seed ^ 0xC0_0CC0);
    rng.sample_indices(n, COOCCURRENCE_SAMPLE_ROWS)
}

impl FeaturePartition {
    /// Pseudo-random hash partition (the paper's layout).
    pub fn hashed(p: usize, m: usize, seed: u64) -> FeaturePartition {
        assert!(m > 0);
        let mut blocks = vec![Vec::new(); m];
        let mut owner = Vec::with_capacity(p);
        for j in 0..p {
            let node = (hash64(j as u64 ^ seed) % m as u64) as usize;
            blocks[node].push(j);
            owner.push(node);
        }
        FeaturePartition { blocks, owner }
    }

    /// Contiguous partition (for tests / worst-case correlation layout).
    pub fn contiguous(p: usize, m: usize) -> FeaturePartition {
        assert!(m > 0);
        let mut blocks = vec![Vec::new(); m];
        let mut owner = Vec::with_capacity(p);
        let chunk = p.div_ceil(m);
        for j in 0..p {
            let node = (j / chunk).min(m - 1);
            blocks[node].push(j);
            owner.push(node);
        }
        FeaturePartition { blocks, owner }
    }

    /// Greedy nnz-balanced partition: features sorted by column nnz
    /// descending, each assigned to the currently lightest node (LPT
    /// scheduling). Minimizes per-iteration compute skew.
    ///
    /// Load ties break toward the LOWEST node index: `Iterator::min_by_key`
    /// returns the *first* minimum and candidates are scanned `0..m`, so the
    /// assignment is fully deterministic (pinned by
    /// `nnz_balanced_tie_breaks_to_lowest_index`).
    pub fn nnz_balanced(x: &Csc, m: usize) -> FeaturePartition {
        assert!(m > 0);
        let p = x.ncols;
        let mut order: Vec<usize> = (0..p).collect();
        order.sort_unstable_by_key(|&j| std::cmp::Reverse(x.col_nnz(j)));
        let mut load = vec![0usize; m];
        let mut blocks = vec![Vec::new(); m];
        let mut owner = vec![0usize; p];
        for j in order {
            let node = (0..m).min_by_key(|&k| load[k]).unwrap();
            load[node] += x.col_nnz(j).max(1);
            blocks[node].push(j);
            owner[j] = node;
        }
        for b in blocks.iter_mut() {
            b.sort_unstable();
        }
        FeaturePartition { blocks, owner }
    }

    /// Correlation-aware partition: cluster columns by co-occurrence on a
    /// deterministic row sample so correlated features land in the same
    /// block (Scherrer et al. 2012, Bradley et al. 2011 — cross-block
    /// correlation is what slows block-separable CD down).
    ///
    /// Greedy agglomerative assignment: columns are visited in descending
    /// sampled-activity order (ties to the lowest feature id) and each joins
    /// the block with the highest co-occurrence affinity — the number of
    /// (sampled row, already-assigned column) pairs it shares with the
    /// block — subject to an nnz-balance cap of `(1 + SLACK)/m` of the total
    /// load. Zero affinity (or a full block) falls back to the lightest
    /// block, lowest index first. Deterministic in `(x, m, seed)`.
    pub fn cooccurrence_clustered(x: &Csc, m: usize, seed: u64) -> FeaturePartition {
        assert!(m > 0);
        let p = x.ncols;
        // Per-column sampled row lists + sampled activity, one O(nnz) pass.
        let sample = sample_rows(x.nrows, seed);
        let mut slot_of = vec![usize::MAX; x.nrows];
        for (s, &r) in sample.iter().enumerate() {
            slot_of[r] = s;
        }
        let mut col_rows: Vec<Vec<usize>> = vec![Vec::new(); p];
        for j in 0..p {
            let (rows, _) = x.col_raw(j);
            for &r in rows {
                let s = slot_of[r as usize];
                if s != usize::MAX {
                    col_rows[j].push(s);
                }
            }
        }
        // Balance cap: no block may exceed its fair nnz share by more than
        // SLACK, so clustering can never trade all balance for affinity.
        const SLACK: f64 = 0.2;
        let total: usize = (0..p).map(|j| x.col_nnz(j).max(1)).sum();
        let cap = ((total as f64) * (1.0 + SLACK) / m as f64).ceil() as usize;

        let mut order: Vec<usize> = (0..p).collect();
        order.sort_unstable_by_key(|&j| (std::cmp::Reverse(col_rows[j].len()), j));

        // coverage[b][s] = columns of block b active in sampled row s.
        let mut coverage = vec![vec![0usize; sample.len()]; m];
        let mut load = vec![0usize; m];
        let mut blocks = vec![Vec::new(); m];
        let mut owner = vec![0usize; p];
        for j in order {
            let mut best: Option<(usize, usize)> = None; // (affinity, block)
            for (b, cov) in coverage.iter().enumerate() {
                if load[b] + x.col_nnz(j).max(1) > cap {
                    continue;
                }
                let affinity: usize = col_rows[j].iter().map(|&s| cov[s]).sum();
                // Strict > keeps the lowest-index block on affinity ties.
                let better = match best {
                    None => true,
                    Some((a, _)) => affinity > a,
                };
                if better {
                    best = Some((affinity, b));
                }
            }
            let node = match best {
                // Real affinity: join the most-correlated block under cap.
                Some((a, b)) if a > 0 => b,
                // No signal (or every block capped): lightest block wins,
                // lowest index first — degrades to LPT balancing.
                _ => (0..m).min_by_key(|&k| load[k]).unwrap(),
            };
            load[node] += x.col_nnz(j).max(1);
            for &s in &col_rows[j] {
                coverage[node][s] += 1;
            }
            blocks[node].push(j);
            owner[j] = node;
        }
        for b in blocks.iter_mut() {
            b.sort_unstable();
        }
        FeaturePartition { blocks, owner }
    }

    /// Per-block cross-block co-occurrence fraction on a deterministic row
    /// sample — the cut diagnostic next to `skew`. For block r, over sampled
    /// rows i with active set A_i and in-block part B = A_i ∩ S^r:
    /// cross = Σ_i |B|·(|A_i|−|B|) (pairs leaving the block) over
    /// total = Σ_i |B|·(|A_i|−1) (all pairs touching the block). 0 = no
    /// correlated feature crosses a boundary, →1 = every pair does; 0 also
    /// when the block never co-occurs with anything (total = 0).
    pub fn cut_fractions(&self, x: &Csc, seed: u64) -> Vec<f64> {
        let m = self.num_nodes();
        let sample = sample_rows(x.nrows, seed);
        let mut slot_of = vec![usize::MAX; x.nrows];
        for (s, &r) in sample.iter().enumerate() {
            slot_of[r] = s;
        }
        // in_block[s][b] = |A_s ∩ S^b|, active[s] = |A_s| (sampled rows).
        let mut in_block = vec![vec![0usize; m]; sample.len()];
        let mut active = vec![0usize; sample.len()];
        for j in 0..x.ncols {
            let (rows, _) = x.col_raw(j);
            for &r in rows {
                let s = slot_of[r as usize];
                if s != usize::MAX {
                    in_block[s][self.owner[j]] += 1;
                    active[s] += 1;
                }
            }
        }
        (0..m)
            .map(|b| {
                let mut cross = 0usize;
                let mut total = 0usize;
                for (s, &a) in active.iter().enumerate() {
                    let k = in_block[s][b];
                    cross += k * (a - k);
                    total += k * (a.saturating_sub(1));
                }
                if total == 0 {
                    0.0
                } else {
                    cross as f64 / total as f64
                }
            })
            .collect()
    }

    pub fn num_nodes(&self) -> usize {
        self.blocks.len()
    }

    pub fn num_features(&self) -> usize {
        self.owner.len()
    }

    /// Materialize node m's column block X^m from the global matrix.
    pub fn shard(&self, x: &Csc, m: usize) -> Csc {
        x.select_cols(&self.blocks[m])
    }

    /// Per-node nnz loads (skew diagnostics; drives slow-node experiments).
    pub fn nnz_loads(&self, x: &Csc) -> Vec<usize> {
        self.blocks
            .iter()
            .map(|b| b.iter().map(|&j| x.col_nnz(j)).sum())
            .collect()
    }

    /// max/mean nnz load ratio — 1.0 is perfectly balanced. When every nnz
    /// load is zero (an all-zero matrix) the ratio falls back to per-block
    /// *column counts*, so an empty block next to a loaded one still
    /// surfaces as skew instead of flattening to 1.0; only a partition with
    /// nothing to balance at all (p = 0) reports 1.0.
    pub fn skew(&self, x: &Csc) -> f64 {
        fn ratio(loads: &[usize]) -> Option<f64> {
            let max = *loads.iter().max().unwrap_or(&0) as f64;
            let mean = loads.iter().sum::<usize>() as f64 / loads.len().max(1) as f64;
            if mean == 0.0 {
                None
            } else {
                Some(max / mean)
            }
        }
        ratio(&self.nnz_loads(x)).unwrap_or_else(|| {
            let cols: Vec<usize> = self.blocks.iter().map(|b| b.len()).collect();
            ratio(&cols).unwrap_or(1.0)
        })
    }

    /// Scatter a concatenation of per-block weight vectors back to global
    /// feature order. `block_weights[m]` is indexed like `blocks[m]`.
    pub fn unshard_weights(&self, block_weights: &[Vec<f64>]) -> Vec<f64> {
        let mut beta = vec![0.0; self.num_features()];
        for (m, block) in self.blocks.iter().enumerate() {
            assert_eq!(block.len(), block_weights[m].len());
            for (local, &j) in block.iter().enumerate() {
                beta[j] = block_weights[m][local];
            }
        }
        beta
    }
}

/// Assignment of examples to M nodes (round-robin or hashed).
#[derive(Clone, Debug)]
pub struct ExamplePartition {
    pub blocks: Vec<Vec<usize>>,
}

impl ExamplePartition {
    pub fn round_robin(n: usize, m: usize) -> ExamplePartition {
        assert!(m > 0);
        let mut blocks = vec![Vec::new(); m];
        for i in 0..n {
            blocks[i % m].push(i);
        }
        ExamplePartition { blocks }
    }

    pub fn hashed(n: usize, m: usize, seed: u64) -> ExamplePartition {
        assert!(m > 0);
        let mut blocks = vec![Vec::new(); m];
        for i in 0..n {
            blocks[(hash64(i as u64 ^ seed) % m as u64) as usize].push(i);
        }
        ExamplePartition { blocks }
    }

    pub fn shard(&self, x: &Csr, m: usize) -> Csr {
        x.select_rows(&self.blocks[m])
    }

    pub fn shard_labels(&self, y: &[f64], m: usize) -> Vec<f64> {
        self.blocks[m].iter().map(|&i| y[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, check_is_partition};

    #[test]
    fn prop_hashed_is_partition() {
        prop::check("hashed partition disjoint+complete", 50, |rng| {
            let p = 1 + rng.below(200);
            let m = 1 + rng.below(16);
            let fp = FeaturePartition::hashed(p, m, rng.next_u64());
            check_is_partition(&fp, p)
        });
    }

    #[test]
    fn prop_contiguous_is_partition() {
        prop::check("contiguous partition disjoint+complete", 50, |rng| {
            let p = 1 + rng.below(200);
            let m = 1 + rng.below(16);
            check_is_partition(&FeaturePartition::contiguous(p, m), p)
        });
    }

    /// Satellite invariant: every named strategy — including the
    /// data-dependent ones — yields a disjoint sorted cover of 0..p for
    /// random (p, m, seed) and a random sparse matrix.
    #[test]
    fn prop_every_strategy_is_partition() {
        prop::check("all strategies disjoint sorted cover", 40, |rng| {
            let p = 1 + rng.below(120);
            let m = 1 + rng.below(8);
            let n = 1 + rng.below(60);
            let seed = rng.next_u64();
            let mut trips = Vec::new();
            for _ in 0..rng.below(300) {
                trips.push((rng.below(n), rng.below(p), rng.range_f64(-2.0, 2.0)));
            }
            let x = Csc::from_triplets(n, p, trips);
            for strat in PartitionStrategy::ALL {
                let fp = strat.resolve(&x, m, seed);
                check_is_partition(&fp, p).map_err(|e| format!("{}: {e}", strat.name()))?;
                if fp.num_nodes() != m {
                    return Err(format!("{}: {} blocks, want {m}", strat.name(), fp.num_nodes()));
                }
                // The dims-only shortcut must agree with the full resolve.
                if let Some(short) = strat.resolve_dims(p, m, seed) {
                    if short.owner != fp.owner {
                        return Err(format!("{}: resolve_dims diverged", strat.name()));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn strategy_parse_name_tag_roundtrip() {
        for strat in PartitionStrategy::ALL {
            assert_eq!(PartitionStrategy::parse(strat.name()), Some(strat));
            assert_eq!(PartitionStrategy::from_tag(strat.tag()).unwrap(), strat);
        }
        assert_eq!(PartitionStrategy::parse("metis"), None);
        assert!(PartitionStrategy::from_tag(9).is_err());
        assert_eq!(PartitionStrategy::default(), PartitionStrategy::Hashed);
        assert!(!PartitionStrategy::Hashed.needs_matrix());
        assert!(!PartitionStrategy::Contiguous.needs_matrix());
        assert!(PartitionStrategy::NnzBalanced.needs_matrix());
        assert!(PartitionStrategy::Clustered.needs_matrix());
        assert!(PartitionStrategy::NnzBalanced.resolve_dims(10, 2, 0).is_none());
        assert!(PartitionStrategy::Clustered.resolve_dims(10, 2, 0).is_none());
    }

    #[test]
    fn hashed_deterministic_per_seed() {
        let a = FeaturePartition::hashed(100, 4, 7);
        let b = FeaturePartition::hashed(100, 4, 7);
        assert_eq!(a.owner, b.owner);
        let c = FeaturePartition::hashed(100, 4, 8);
        assert_ne!(a.owner, c.owner);
    }

    #[test]
    fn hashed_roughly_balanced() {
        let fp = FeaturePartition::hashed(10_000, 8, 1);
        for b in &fp.blocks {
            let frac = b.len() as f64 / 10_000.0;
            assert!((frac - 0.125).abs() < 0.02, "block frac {frac}");
        }
    }

    #[test]
    fn nnz_balanced_beats_hash_on_skewed_data() {
        // Power-law columns: column j has ~1000/(j+1) entries.
        let mut trips = Vec::new();
        for j in 0..50usize {
            let cnt = (1000 / (j + 1)).max(1);
            for i in 0..cnt {
                trips.push((i % 500, j, 1.0));
            }
        }
        let x = Csc::from_triplets(500, 50, trips);
        let hash_skew = FeaturePartition::hashed(50, 4, 3).skew(&x);
        let bal_skew = FeaturePartition::nnz_balanced(&x, 4).skew(&x);
        assert!(
            bal_skew <= hash_skew + 1e-9,
            "balanced {bal_skew} vs hashed {hash_skew}"
        );
        assert!(bal_skew < 1.2, "balanced skew too high: {bal_skew}");
    }

    /// Regression pin for the LPT tie-break: equal loads go to the lowest
    /// node index (min_by_key returns the first minimum). With strictly
    /// decreasing column nnz the visit order is the identity, so the whole
    /// assignment is forced: 0→n0, 1→n1 (0 is heavier), 2→n1 (4<5), 3→n0.
    #[test]
    fn nnz_balanced_tie_breaks_to_lowest_index() {
        let mut trips = Vec::new();
        for (j, cnt) in [5usize, 4, 3, 2].into_iter().enumerate() {
            for i in 0..cnt {
                trips.push((i, j, 1.0));
            }
        }
        let x = Csc::from_triplets(5, 4, trips);
        let fp = FeaturePartition::nnz_balanced(&x, 2);
        assert_eq!(fp.blocks, vec![vec![0, 3], vec![1, 2]]);
        assert_eq!(fp.owner, vec![0, 1, 1, 0]);
        // The all-tied degenerate case: one column, three nodes — the
        // zero-load tie resolves to node 0, never 1 or 2.
        let one = Csc::from_triplets(2, 1, vec![(0, 0, 1.0)]);
        let fp1 = FeaturePartition::nnz_balanced(&one, 3);
        assert_eq!(fp1.owner, vec![0]);
        assert_eq!(fp1.blocks, vec![vec![0], vec![], vec![]]);
    }

    /// Empty blocks must surface as imbalance, not hide behind 1.0: an
    /// all-zero matrix has zero nnz everywhere, so skew falls back to the
    /// column-count ratio.
    #[test]
    fn skew_surfaces_empty_blocks_on_zero_nnz() {
        let zero = Csc::from_triplets(3, 4, Vec::<(usize, usize, f64)>::new());
        // All 4 columns on node 0 of 2: column-count loads [4, 0] → 4/2 = 2.
        let lopsided = FeaturePartition {
            blocks: vec![vec![0, 1, 2, 3], vec![]],
            owner: vec![0; 4],
        };
        assert_eq!(lopsided.skew(&zero), 2.0);
        // Balanced columns over a zero matrix really are balanced.
        let even = FeaturePartition::contiguous(4, 2);
        assert_eq!(even.skew(&zero), 1.0);
        // Nothing to balance at all: stays 1.0.
        let empty = FeaturePartition::contiguous(0, 2);
        let none = Csc::from_triplets(3, 0, Vec::<(usize, usize, f64)>::new());
        assert_eq!(empty.skew(&none), 1.0);
    }

    /// Two independent column groups (rows touch only one group): the
    /// clusterer must separate them, driving its cut fractions to ~0 while
    /// hashed mixes the groups and pays ~1/2 cross-block pairs.
    #[test]
    fn clustered_separates_block_structure_and_cuts_less_than_hashed() {
        let mut trips = Vec::new();
        let mut rng = crate::util::rng::Rng::new(9);
        for i in 0..200usize {
            let group = i % 2;
            // Anchor column per group: guarantees every group column
            // co-occurs with its group's seed block at assignment time.
            trips.push((i, 20 * group, 1.0));
            for _ in 0..5 {
                let j = 20 * group + rng.below(20);
                trips.push((i, j, 1.0 + rng.f64()));
            }
        }
        let x = Csc::from_triplets(200, 40, trips);
        let fp = FeaturePartition::cooccurrence_clustered(&x, 2, 1);
        check_is_partition(&fp, 40).unwrap();
        // Each block holds exactly one group.
        for block in &fp.blocks {
            let groups: std::collections::HashSet<usize> =
                block.iter().map(|&j| j / 20).collect();
            assert_eq!(groups.len(), 1, "block mixes groups: {block:?}");
        }
        let cut_clustered = fp.cut_fractions(&x, 1);
        let cut_hashed = FeaturePartition::hashed(40, 2, 1).cut_fractions(&x, 1);
        for (c, h) in cut_clustered.iter().zip(cut_hashed.iter()) {
            assert!(*c < 1e-9, "clustered cut should be ~0, got {c}");
            assert!(*h > 0.3, "hashed cut should mix the groups, got {h}");
        }
        // Balance survives clustering: the cap keeps the groups even here.
        assert!(fp.skew(&x) < 1.25, "clustered skew {}", fp.skew(&x));
    }

    #[test]
    fn clustered_deterministic_per_seed() {
        let mut trips = Vec::new();
        let mut rng = crate::util::rng::Rng::new(4);
        for _ in 0..400 {
            trips.push((rng.below(80), rng.below(60), rng.range_f64(-1.0, 1.0)));
        }
        let x = Csc::from_triplets(80, 60, trips);
        let a = FeaturePartition::cooccurrence_clustered(&x, 4, 11);
        let b = FeaturePartition::cooccurrence_clustered(&x, 4, 11);
        assert_eq!(a.owner, b.owner);
        check_is_partition(&a, 60).unwrap();
    }

    /// A fully uncorrelated layout (single-entry columns, disjoint rows) has
    /// no co-occurrence at all — every strategy's cut is 0 and the clusterer
    /// degrades to pure load balancing.
    #[test]
    fn cut_fraction_zero_without_cooccurrence() {
        let trips: Vec<(usize, usize, f64)> = (0..10).map(|j| (j, j, 1.0)).collect();
        let x = Csc::from_triplets(10, 10, trips);
        let fp = FeaturePartition::cooccurrence_clustered(&x, 2, 3);
        check_is_partition(&fp, 10).unwrap();
        assert_eq!(fp.blocks[0].len(), 5);
        for c in fp.cut_fractions(&x, 3) {
            assert_eq!(c, 0.0);
        }
    }

    #[test]
    fn shard_and_unshard_roundtrip() {
        let x = Csc::from_triplets(
            4,
            6,
            vec![
                (0, 0, 1.0),
                (1, 1, 2.0),
                (2, 2, 3.0),
                (3, 3, 4.0),
                (0, 4, 5.0),
                (1, 5, 6.0),
            ],
        );
        let fp = FeaturePartition::hashed(6, 3, 42);
        // per-block weights = global feature id as value
        let block_weights: Vec<Vec<f64>> = fp
            .blocks
            .iter()
            .map(|b| b.iter().map(|&j| j as f64).collect())
            .collect();
        let beta = fp.unshard_weights(&block_weights);
        assert_eq!(beta, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        // shard column count matches block size
        for m in 0..3 {
            assert_eq!(fp.shard(&x, m).ncols, fp.blocks[m].len());
        }
    }

    #[test]
    fn example_partition_covers_all() {
        for m in [1, 3, 8] {
            let ep = ExamplePartition::round_robin(100, m);
            let total: usize = ep.blocks.iter().map(|b| b.len()).sum();
            assert_eq!(total, 100);
            let mut all: Vec<usize> = ep.blocks.concat();
            all.sort_unstable();
            assert_eq!(all, (0..100).collect::<Vec<_>>());
        }
    }

    #[test]
    fn example_shard_labels_align() {
        let x = Csr::from_rows(
            2,
            &[
                vec![(0, 1.0)],
                vec![(1, 2.0)],
                vec![(0, 3.0)],
                vec![(1, 4.0)],
            ],
        );
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let ep = ExamplePartition::round_robin(4, 2);
        let s0 = ep.shard(&x, 0);
        let y0 = ep.shard_labels(&y, 0);
        assert_eq!(s0.nrows, 2);
        assert_eq!(y0, vec![1.0, 1.0]);
    }
}
