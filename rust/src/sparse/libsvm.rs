//! libsvm / svmlight format reader and writer.
//!
//! Format: one example per line, `label idx:val idx:val ...` with 1-based
//! feature indices (we also accept 0-based via `IndexBase::Zero`). Reading is
//! streaming (BufRead) so real Pascal-challenge files (epsilon, webspam) can
//! be swapped in for the synthetic generators without loading twice.

use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

use crate::sparse::csr::Csr;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IndexBase {
    Zero,
    One,
}

/// Largest 0-based feature index any ingestion path accepts (text parse here
/// and the binary shard-header validator in `data::shards`). Chosen so the
/// index fits the `u32` column ids the sparse matrices store and `idx + 1`
/// (the implied width) cannot wrap `usize` on hostile input.
pub const MAX_FEATURE_INDEX: usize = (u32::MAX - 1) as usize;

/// A labeled sparse dataset in example-major order.
#[derive(Clone, Debug)]
pub struct LibsvmData {
    pub x: Csr,
    /// Labels in {-1, +1} for classification, arbitrary reals for regression.
    pub y: Vec<f64>,
}

#[derive(Debug, thiserror::Error)]
pub enum LibsvmError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("line {line}: {msg}")]
    Parse { line: usize, msg: String },
}

/// Parse from any reader. `ncols_hint` may extend the feature space (useful
/// to keep train/test aligned); the actual width is max(hint, max index + 1).
pub fn read<R: Read>(
    reader: R,
    base: IndexBase,
    ncols_hint: usize,
) -> Result<LibsvmData, LibsvmError> {
    let buf = BufReader::new(reader);
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut y = Vec::new();
    let mut max_col = 0usize;
    for (lineno, line) in buf.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label: f64 = parts
            .next()
            .unwrap()
            .parse()
            .map_err(|e| LibsvmError::Parse {
                line: lineno + 1,
                msg: format!("bad label: {e}"),
            })?;
        let mut row = Vec::new();
        for tok in parts {
            if tok.starts_with('#') {
                break;
            }
            let (is, vs) = tok.split_once(':').ok_or_else(|| LibsvmError::Parse {
                line: lineno + 1,
                msg: format!("expected idx:val, got '{tok}'"),
            })?;
            let idx: usize = is.parse().map_err(|e| LibsvmError::Parse {
                line: lineno + 1,
                msg: format!("bad index '{is}': {e}"),
            })?;
            let idx = match base {
                IndexBase::Zero => idx,
                IndexBase::One => {
                    if idx == 0 {
                        return Err(LibsvmError::Parse {
                            line: lineno + 1,
                            msg: "index 0 in 1-based file".into(),
                        });
                    }
                    idx - 1
                }
            };
            if idx > MAX_FEATURE_INDEX {
                return Err(LibsvmError::Parse {
                    line: lineno + 1,
                    msg: format!("feature index {idx} above the supported bound {MAX_FEATURE_INDEX}"),
                });
            }
            let val: f64 = vs.parse().map_err(|e| LibsvmError::Parse {
                line: lineno + 1,
                msg: format!("bad value '{vs}': {e}"),
            })?;
            max_col = max_col.max(idx + 1);
            row.push((idx, val));
        }
        rows.push(row);
        y.push(label);
    }
    let ncols = max_col.max(ncols_hint);
    Ok(LibsvmData {
        x: Csr::from_rows(ncols, &rows),
        y,
    })
}

/// Read from a file path (1-based indices, the standard convention).
pub fn read_file(path: impl AsRef<Path>) -> Result<LibsvmData, LibsvmError> {
    let f = std::fs::File::open(path)?;
    read(f, IndexBase::One, 0)
}

/// Write in 1-based libsvm format (the standard convention).
pub fn write<W: Write>(w: &mut W, data: &LibsvmData) -> std::io::Result<()> {
    write_with_base(w, data, IndexBase::One)
}

/// Write with an explicit index base, mirroring what [`read`] accepts. A
/// write→read round trip under the same base reproduces the matrix exactly
/// (up to trailing all-zero columns — pass the original width as
/// `ncols_hint` when re-reading to preserve those).
pub fn write_with_base<W: Write>(
    w: &mut W,
    data: &LibsvmData,
    base: IndexBase,
) -> std::io::Result<()> {
    let offset = match base {
        IndexBase::Zero => 0,
        IndexBase::One => 1,
    };
    for i in 0..data.x.nrows {
        let label = data.y[i];
        // Integer fast-path only when the cast is exact: an integral f64 with
        // |label| ≤ 2^53 is representable in i64 without saturation. Anything
        // larger (or non-finite) round-trips through f64's own formatting.
        if label == label.trunc() && label.abs() <= 9_007_199_254_740_992.0 {
            write!(w, "{}", label as i64)?;
        } else {
            write!(w, "{label}")?;
        }
        for (c, v) in data.x.row(i) {
            write!(w, " {}:{}", c + offset, v)?;
        }
        writeln!(w)?;
    }
    Ok(())
}

pub fn write_file(path: impl AsRef<Path>, data: &LibsvmData) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write(&mut f, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
+1 1:0.5 3:1.25
-1 2:2
# comment line
+1 1:-1 2:0.125 3:3
";

    #[test]
    fn parse_sample() {
        let d = read(SAMPLE.as_bytes(), IndexBase::One, 0).unwrap();
        assert_eq!(d.x.nrows, 3);
        assert_eq!(d.x.ncols, 3);
        assert_eq!(d.y, vec![1.0, -1.0, 1.0]);
        assert_eq!(d.x.row(0).collect::<Vec<_>>(), vec![(0, 0.5), (2, 1.25)]);
        assert_eq!(d.x.row(1).collect::<Vec<_>>(), vec![(1, 2.0)]);
    }

    #[test]
    fn ncols_hint_extends() {
        let d = read(SAMPLE.as_bytes(), IndexBase::One, 10).unwrap();
        assert_eq!(d.x.ncols, 10);
    }

    #[test]
    fn zero_based_mode() {
        let d = read("1 0:1.5 2:2.5\n".as_bytes(), IndexBase::Zero, 0).unwrap();
        assert_eq!(d.x.ncols, 3);
        assert_eq!(d.x.row(0).collect::<Vec<_>>(), vec![(0, 1.5), (2, 2.5)]);
    }

    #[test]
    fn rejects_zero_index_in_one_based() {
        assert!(read("1 0:1.5\n".as_bytes(), IndexBase::One, 0).is_err());
    }

    #[test]
    fn rejects_malformed_pair() {
        assert!(read("1 15\n".as_bytes(), IndexBase::One, 0).is_err());
        assert!(read("1 a:b\n".as_bytes(), IndexBase::One, 0).is_err());
    }

    #[test]
    fn rejects_indices_above_the_feature_bound() {
        // Regression: a hostile 0-based index of usize::MAX used to wrap in
        // `max_col.max(idx + 1)` (release) or panic (debug). Now a Parse
        // error, in both bases, as is anything past MAX_FEATURE_INDEX.
        let huge = format!("1 {}:1.0\n", usize::MAX);
        let err = read(huge.as_bytes(), IndexBase::Zero, 0).unwrap_err();
        assert!(err.to_string().contains("above the supported bound"), "{err}");
        assert!(read(huge.as_bytes(), IndexBase::One, 0).is_err());
        let over = format!("1 {}:1.0\n", MAX_FEATURE_INDEX + 1);
        assert!(read(over.as_bytes(), IndexBase::Zero, 0).is_err());
        // The bound itself is accepted (1-based: idx-1 lands exactly on it).
        let at = format!("1 {}:1.0\n", MAX_FEATURE_INDEX);
        let d = read(at.as_bytes(), IndexBase::Zero, 0).unwrap();
        assert_eq!(d.x.ncols, MAX_FEATURE_INDEX + 1);
    }

    #[test]
    fn write_read_roundtrip() {
        let d = read(SAMPLE.as_bytes(), IndexBase::One, 0).unwrap();
        let mut buf = Vec::new();
        write(&mut buf, &d).unwrap();
        let d2 = read(buf.as_slice(), IndexBase::One, 0).unwrap();
        assert_eq!(d.x, d2.x);
        assert_eq!(d.y, d2.y);
    }

    #[test]
    fn prop_write_read_roundtrip_both_bases() {
        use crate::sparse::csr::Csr;
        use crate::util::prop;
        for base in [IndexBase::Zero, IndexBase::One] {
            prop::check("libsvm write→read roundtrip", 60, |rng| {
                let (nr, nc) = (1 + rng.below(12), 1 + rng.below(15));
                let mut rows: Vec<Vec<(usize, f64)>> = (0..nr)
                    .map(|_| prop::sparse_vec(rng, nc, 8, 4.0))
                    .collect();
                // Force an empty-feature row (label only, no idx:val pairs)
                // into every case — the regression this prop pins down.
                rows[0].clear();
                let y: Vec<f64> = (0..nr)
                    .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
                    .collect();
                let d = LibsvmData {
                    x: Csr::from_rows(nc, &rows),
                    y,
                };
                let mut buf = Vec::new();
                write_with_base(&mut buf, &d, base)
                    .map_err(|e| format!("write failed: {e}"))?;
                // Re-read with the original width as hint: trailing all-zero
                // columns are not representable in the text format itself.
                let d2 = read(buf.as_slice(), base, nc)
                    .map_err(|e| format!("read failed: {e}"))?;
                if d2.x != d.x {
                    return Err(format!("matrix mismatch under {base:?}"));
                }
                if d2.y != d.y {
                    return Err(format!("label mismatch under {base:?}"));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn huge_integral_labels_roundtrip_exactly() {
        use crate::sparse::csr::Csr;
        // Regression: `label as i64` saturated for integral labels outside
        // i64 range (e.g. 1e300), so the written text no longer matched the
        // label. The fast-path now applies only below 2^53.
        let y = vec![1e300, -1e300, 9_007_199_254_740_992.0, 1e16, 2.5, -1.0];
        let d = LibsvmData {
            x: Csr::from_rows(2, &vec![vec![(0, 1.0)]; 6]),
            y,
        };
        let mut buf = Vec::new();
        write(&mut buf, &d).unwrap();
        let d2 = read(buf.as_slice(), IndexBase::One, 2).unwrap();
        for (a, b) in d.y.iter().zip(d2.y.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} did not round-trip");
        }
    }

    #[test]
    fn empty_feature_row_survives_roundtrip() {
        use crate::sparse::csr::Csr;
        // One row with features, one with none, one with none at the end.
        let d = LibsvmData {
            x: Csr::from_rows(3, &[vec![(1, 2.5)], vec![], vec![]]),
            y: vec![1.0, -1.0, 1.0],
        };
        for base in [IndexBase::Zero, IndexBase::One] {
            let mut buf = Vec::new();
            write_with_base(&mut buf, &d, base).unwrap();
            let d2 = read(buf.as_slice(), base, 3).unwrap();
            assert_eq!(d2.x, d.x, "{base:?}");
            assert_eq!(d2.y, d.y, "{base:?}");
            assert_eq!(d2.x.row_nnz(1), 0);
        }
    }
}
