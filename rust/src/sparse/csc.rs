//! Compressed sparse column matrix — the worker-side ("by feature") layout.
//!
//! d-GLMNET shards the design matrix X vertically: node m stores the columns
//! in its feature block S^m. Coordinate descent walks one column at a time
//! (`Σ_i w_i x_ij r_i`, `Σ_i w_i x_ij²`, then scatter `t_i += δ x_ij`), so
//! CSC gives exactly the O(nnz(col)) access pattern of Algorithm 2.

use crate::sparse::csr::Csr;

/// CSC sparse matrix with f64 values and usize row indices.
#[derive(Clone, Debug, PartialEq)]
pub struct Csc {
    /// Number of rows (examples).
    pub nrows: usize,
    /// Number of columns (features).
    pub ncols: usize,
    /// Column pointer array, length ncols + 1.
    pub colptr: Vec<usize>,
    /// Row index of each stored entry, length nnz.
    pub rowidx: Vec<u32>,
    /// Value of each stored entry, length nnz.
    pub values: Vec<f64>,
}

impl Csc {
    /// Build from (row, col, value) triplets; duplicates are summed.
    pub fn from_triplets(
        nrows: usize,
        ncols: usize,
        triplets: impl IntoIterator<Item = (usize, usize, f64)>,
    ) -> Csc {
        let mut cols: Vec<Vec<(u32, f64)>> = vec![Vec::new(); ncols];
        for (r, c, v) in triplets {
            assert!(r < nrows && c < ncols, "triplet ({r},{c}) out of bounds");
            cols[c].push((r as u32, v));
        }
        let mut colptr = Vec::with_capacity(ncols + 1);
        let mut rowidx = Vec::new();
        let mut values = Vec::new();
        colptr.push(0);
        for col in cols.iter_mut() {
            col.sort_unstable_by_key(|&(r, _)| r);
            let mut i = 0;
            while i < col.len() {
                let (r, mut v) = col[i];
                let mut j = i + 1;
                while j < col.len() && col[j].0 == r {
                    v += col[j].1;
                    j += 1;
                }
                if v != 0.0 {
                    rowidx.push(r);
                    values.push(v);
                }
                i = j;
            }
            colptr.push(rowidx.len());
        }
        Csc {
            nrows,
            ncols,
            colptr,
            rowidx,
            values,
        }
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Iterate over (row, value) of column j.
    #[inline]
    pub fn col(&self, j: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let (lo, hi) = (self.colptr[j], self.colptr[j + 1]);
        self.rowidx[lo..hi]
            .iter()
            .zip(self.values[lo..hi].iter())
            .map(|(&r, &v)| (r as usize, v))
    }

    /// Raw slices of column j, for the allocation-free hot loop.
    #[inline]
    pub fn col_raw(&self, j: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.colptr[j], self.colptr[j + 1]);
        (&self.rowidx[lo..hi], &self.values[lo..hi])
    }

    /// nnz of column j.
    #[inline]
    pub fn col_nnz(&self, j: usize) -> usize {
        self.colptr[j + 1] - self.colptr[j]
    }

    /// y += alpha * X[:, j] * coef  — scatter a scaled column into a dense vec.
    #[inline]
    pub fn axpy_col(&self, j: usize, coef: f64, y: &mut [f64]) {
        assert!(y.len() >= self.nrows);
        let (rows, vals) = self.col_raw(j);
        // SAFETY: constructors keep every rowidx < nrows ≤ y.len().
        unsafe { crate::kernels::active().axpy_col(rows, vals, coef, y) }
    }

    /// Dense matrix-vector product y = X * beta (beta indexed by column).
    pub fn mul_vec(&self, beta: &[f64]) -> Vec<f64> {
        assert_eq!(beta.len(), self.ncols);
        let mut y = vec![0.0; self.nrows];
        for j in 0..self.ncols {
            let b = beta[j];
            if b != 0.0 {
                self.axpy_col(j, b, &mut y);
            }
        }
        y
    }

    /// Transpose-product g = Xᵀ v (g indexed by column).
    pub fn tmul_vec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.nrows);
        let ker = crate::kernels::active();
        let mut g = vec![0.0; self.ncols];
        for j in 0..self.ncols {
            let (rows, vals) = self.col_raw(j);
            // SAFETY: constructors keep every rowidx < nrows == v.len().
            g[j] = unsafe { ker.sparse_dot(rows, vals, v) };
        }
        g
    }

    /// Select a subset of columns (in the given order) into a new matrix.
    /// Used by the feature partitioner to build each node's block X^m.
    pub fn select_cols(&self, cols: &[usize]) -> Csc {
        let mut colptr = Vec::with_capacity(cols.len() + 1);
        let nnz: usize = cols.iter().map(|&j| self.col_nnz(j)).sum();
        let mut rowidx = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        colptr.push(0);
        for &j in cols {
            assert!(j < self.ncols);
            let (rows, vals) = self.col_raw(j);
            rowidx.extend_from_slice(rows);
            values.extend_from_slice(vals);
            colptr.push(rowidx.len());
        }
        Csc {
            nrows: self.nrows,
            ncols: cols.len(),
            colptr,
            rowidx,
            values,
        }
    }

    /// Copy a contiguous column range into a new matrix — one memcpy of the
    /// range's entries plus a rebased colptr, no per-column index list.
    /// Used by the hybrid CD mode to materialize each sub-block shard.
    pub fn slice_cols(&self, range: std::ops::Range<usize>) -> Csc {
        assert!(range.start <= range.end && range.end <= self.ncols);
        let (lo, hi) = (self.colptr[range.start], self.colptr[range.end]);
        let colptr: Vec<usize> = self.colptr[range.start..=range.end]
            .iter()
            .map(|p| p - lo)
            .collect();
        Csc {
            nrows: self.nrows,
            ncols: range.len(),
            colptr,
            rowidx: self.rowidx[lo..hi].to_vec(),
            values: self.values[lo..hi].to_vec(),
        }
    }

    /// Convert to CSR (example-major) layout.
    pub fn to_csr(&self) -> Csr {
        let mut rowcnt = vec![0usize; self.nrows];
        for &r in &self.rowidx {
            rowcnt[r as usize] += 1;
        }
        let mut rowptr = Vec::with_capacity(self.nrows + 1);
        rowptr.push(0usize);
        for c in &rowcnt {
            rowptr.push(rowptr.last().unwrap() + c);
        }
        let mut colidx = vec![0u32; self.nnz()];
        let mut values = vec![0.0; self.nnz()];
        let mut next = rowptr.clone();
        for j in 0..self.ncols {
            let (rows, vals) = self.col_raw(j);
            for (r, v) in rows.iter().zip(vals.iter()) {
                let slot = next[*r as usize];
                colidx[slot] = j as u32;
                values[slot] = *v;
                next[*r as usize] += 1;
            }
        }
        Csr {
            nrows: self.nrows,
            ncols: self.ncols,
            rowptr,
            colidx,
            values,
        }
    }

    /// Squared L2 norm of column j.
    pub fn col_sq_norm(&self, j: usize) -> f64 {
        let (_, vals) = self.col_raw(j);
        crate::kernels::active().sq_norm(vals)
    }

    /// Bytes of payload storage (colptr + rowidx + values) — used by the
    /// Table 2 memory-footprint accounting.
    pub fn storage_bytes(&self) -> usize {
        self.colptr.len() * std::mem::size_of::<usize>()
            + self.rowidx.len() * std::mem::size_of::<u32>()
            + self.values.len() * std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, all_close};
    use crate::util::rng::Rng;

    fn small() -> Csc {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        Csc::from_triplets(
            3,
            3,
            vec![(0, 0, 1.0), (2, 0, 4.0), (1, 1, 3.0), (0, 2, 2.0), (2, 2, 5.0)],
        )
    }

    #[test]
    fn from_triplets_layout() {
        let m = small();
        assert_eq!(m.nnz(), 5);
        assert_eq!(m.colptr, vec![0, 2, 3, 5]);
        assert_eq!(m.col(0).collect::<Vec<_>>(), vec![(0, 1.0), (2, 4.0)]);
        assert_eq!(m.col(1).collect::<Vec<_>>(), vec![(1, 3.0)]);
    }

    #[test]
    fn duplicates_summed_zeros_dropped() {
        let m = Csc::from_triplets(2, 1, vec![(0, 0, 1.0), (0, 0, 2.0), (1, 0, 3.0), (1, 0, -3.0)]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.col(0).collect::<Vec<_>>(), vec![(0, 3.0)]);
    }

    #[test]
    fn mul_vec_known() {
        let m = small();
        let y = m.mul_vec(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![1.0 + 6.0, 6.0, 4.0 + 15.0]);
    }

    #[test]
    fn tmul_vec_known() {
        let m = small();
        let g = m.tmul_vec(&[1.0, 2.0, 3.0]);
        assert_eq!(g, vec![1.0 + 12.0, 6.0, 2.0 + 15.0]);
    }

    #[test]
    fn select_cols_subset() {
        let m = small();
        let s = m.select_cols(&[2, 0]);
        assert_eq!(s.ncols, 2);
        assert_eq!(s.col(0).collect::<Vec<_>>(), vec![(0, 2.0), (2, 5.0)]);
        assert_eq!(s.col(1).collect::<Vec<_>>(), vec![(0, 1.0), (2, 4.0)]);
    }

    #[test]
    fn slice_cols_matches_select_cols() {
        let m = small();
        for range in [0..0, 0..1, 1..3, 0..3] {
            let sliced = m.slice_cols(range.clone());
            let selected = m.select_cols(&range.clone().collect::<Vec<_>>());
            assert_eq!(sliced.ncols, selected.ncols, "{range:?}");
            for j in 0..sliced.ncols {
                assert_eq!(
                    sliced.col(j).collect::<Vec<_>>(),
                    selected.col(j).collect::<Vec<_>>(),
                    "{range:?} col {j}"
                );
            }
        }
    }

    #[test]
    fn to_csr_roundtrip_product() {
        let m = small();
        let r = m.to_csr();
        let beta = [0.5, -1.0, 2.0];
        assert_eq!(m.mul_vec(&beta), r.mul_vec(&beta));
    }

    #[test]
    fn col_sq_norm_known() {
        let m = small();
        assert_eq!(m.col_sq_norm(0), 17.0);
        assert_eq!(m.col_sq_norm(1), 9.0);
    }

    #[test]
    fn prop_mul_matches_dense() {
        prop::check("csc mul = dense mul", 50, |rng| {
            let (nr, nc) = (1 + rng.below(20), 1 + rng.below(20));
            let mut trips = Vec::new();
            let mut dense = vec![vec![0.0; nc]; nr];
            for _ in 0..rng.below(60) {
                let (r, c, v) = (rng.below(nr), rng.below(nc), rng.range_f64(-2.0, 2.0));
                trips.push((r, c, v));
                dense[r][c] += v;
            }
            let m = Csc::from_triplets(nr, nc, trips);
            let beta: Vec<f64> = (0..nc).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let want: Vec<f64> = dense
                .iter()
                .map(|row| row.iter().zip(&beta).map(|(a, b)| a * b).sum())
                .collect();
            all_close(&m.mul_vec(&beta), &want, 1e-12)
        });
    }

    #[test]
    fn prop_tmul_matches_dense() {
        prop::check("csc tmul = dense tmul", 50, |rng| {
            let (nr, nc) = (1 + rng.below(15), 1 + rng.below(15));
            let mut trips = Vec::new();
            let mut dense = vec![vec![0.0; nc]; nr];
            for _ in 0..rng.below(50) {
                let (r, c, v) = (rng.below(nr), rng.below(nc), rng.range_f64(-2.0, 2.0));
                trips.push((r, c, v));
                dense[r][c] += v;
            }
            let m = Csc::from_triplets(nr, nc, trips);
            let v: Vec<f64> = (0..nr).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let want: Vec<f64> = (0..nc)
                .map(|j| (0..nr).map(|i| dense[i][j] * v[i]).sum())
                .collect();
            all_close(&m.tmul_vec(&v), &want, 1e-12)
        });
    }

    #[test]
    fn prop_select_cols_preserves_columns() {
        prop::check("select_cols identity", 30, |rng| {
            let (nr, nc) = (1 + rng.below(10), 2 + rng.below(10));
            let mut trips = Vec::new();
            for _ in 0..rng.below(40) {
                trips.push((rng.below(nr), rng.below(nc), rng.range_f64(-1.0, 1.0)));
            }
            let m = Csc::from_triplets(nr, nc, trips);
            let all: Vec<usize> = (0..nc).collect();
            let s = m.select_cols(&all);
            if s == m {
                Ok(())
            } else {
                Err("identity selection changed matrix".into())
            }
        });
    }

    #[test]
    fn prop_from_triplets_sums_duplicates() {
        // Dense-accumulator oracle: however many times (r, c) repeats in the
        // triplet list, the stored entry is the sum — and exact-zero sums
        // are dropped from the structure entirely.
        prop::check("from_triplets duplicate summing", 60, |rng| {
            let (nr, nc) = (1 + rng.below(8), 1 + rng.below(8));
            let mut trips = Vec::new();
            let mut dense = vec![vec![0.0; nc]; nr];
            // Small index space + many triplets ⇒ duplicates are common;
            // also inject guaranteed duplicates and a cancelling pair.
            for _ in 0..20 + rng.below(40) {
                let (r, c, v) = (rng.below(nr), rng.below(nc), rng.range_f64(-2.0, 2.0));
                trips.push((r, c, v));
                dense[r][c] += v;
            }
            let (r0, c0) = (rng.below(nr), rng.below(nc));
            trips.push((r0, c0, 1.5));
            trips.push((r0, c0, 1.5));
            dense[r0][c0] += 3.0;
            let (r1, c1) = (rng.below(nr), rng.below(nc));
            trips.push((r1, c1, 2.0));
            trips.push((r1, c1, -2.0));
            let m = Csc::from_triplets(nr, nc, trips);
            for j in 0..nc {
                let col: std::collections::HashMap<usize, f64> = m.col(j).collect();
                for (i, row) in dense.iter().enumerate() {
                    let want = row[j];
                    match col.get(&i) {
                        Some(&got) => {
                            prop::close(got, want, 1e-12)
                                .map_err(|e| format!("entry ({i},{j}): {e}"))?;
                            if got == 0.0 {
                                return Err(format!("explicit zero stored at ({i},{j})"));
                            }
                        }
                        None if want != 0.0 => {
                            return Err(format!("missing entry ({i},{j}) = {want}"));
                        }
                        None => {}
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_csc_csr_csc_roundtrip() {
        // Completes the layout round trip (csr.rs checks CSR→CSC→CSR).
        prop::check("csc->csr->csc identity", 40, |rng| {
            let (nr, nc) = (1 + rng.below(12), 1 + rng.below(12));
            let mut trips = Vec::new();
            for _ in 0..rng.below(50) {
                trips.push((rng.below(nr), rng.below(nc), rng.range_f64(-2.0, 2.0)));
            }
            let m = Csc::from_triplets(nr, nc, trips);
            if m.to_csr().to_csc() == m {
                Ok(())
            } else {
                Err("roundtrip mismatch".into())
            }
        });
    }

    #[test]
    fn rng_helper_used() {
        // keep Rng import exercised even if props get pruned
        let mut r = Rng::new(1);
        assert!(r.f64() < 1.0);
    }
}
