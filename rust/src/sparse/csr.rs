//! Compressed sparse row matrix — the example-major layout.
//!
//! Baselines that shard *by example* (online truncated gradient, L-BFGS with
//! distributed gradient sums; Agarwal et al. 2014) stream examples, so they
//! use CSR. `Csr::select_rows` builds each node's example shard.

use crate::sparse::csc::Csc;

/// CSR sparse matrix with f64 values and u32 column indices.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    /// Row pointer array, length nrows + 1.
    pub rowptr: Vec<usize>,
    /// Column index of each stored entry.
    pub colidx: Vec<u32>,
    /// Value of each stored entry.
    pub values: Vec<f64>,
}

impl Csr {
    /// Build from per-row (col, value) lists.
    pub fn from_rows(ncols: usize, rows: &[Vec<(usize, f64)>]) -> Csr {
        let mut rowptr = Vec::with_capacity(rows.len() + 1);
        let mut colidx = Vec::new();
        let mut values = Vec::new();
        rowptr.push(0);
        for row in rows {
            let mut sorted: Vec<(usize, f64)> = row.clone();
            sorted.sort_unstable_by_key(|&(c, _)| c);
            let mut i = 0;
            while i < sorted.len() {
                let (c, mut v) = sorted[i];
                assert!(c < ncols, "column {c} out of bounds");
                let mut j = i + 1;
                while j < sorted.len() && sorted[j].0 == c {
                    v += sorted[j].1;
                    j += 1;
                }
                if v != 0.0 {
                    colidx.push(c as u32);
                    values.push(v);
                }
                i = j;
            }
            rowptr.push(colidx.len());
        }
        Csr {
            nrows: rows.len(),
            ncols,
            rowptr,
            colidx,
            values,
        }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Iterate over (col, value) of row i.
    #[inline]
    pub fn row(&self, i: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let (lo, hi) = (self.rowptr[i], self.rowptr[i + 1]);
        self.colidx[lo..hi]
            .iter()
            .zip(self.values[lo..hi].iter())
            .map(|(&c, &v)| (c as usize, v))
    }

    /// Raw slices of row i.
    #[inline]
    pub fn row_raw(&self, i: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.rowptr[i], self.rowptr[i + 1]);
        (&self.colidx[lo..hi], &self.values[lo..hi])
    }

    pub fn row_nnz(&self, i: usize) -> usize {
        self.rowptr[i + 1] - self.rowptr[i]
    }

    /// Dot product of row i with a dense weight vector.
    #[inline]
    pub fn dot_row(&self, i: usize, beta: &[f64]) -> f64 {
        assert!(beta.len() >= self.ncols);
        let (cols, vals) = self.row_raw(i);
        // SAFETY: constructors keep every colidx < ncols ≤ beta.len().
        unsafe { crate::kernels::active().sparse_dot(cols, vals, beta) }
    }

    /// Dense product y = X * beta.
    pub fn mul_vec(&self, beta: &[f64]) -> Vec<f64> {
        assert_eq!(beta.len(), self.ncols);
        (0..self.nrows).map(|i| self.dot_row(i, beta)).collect()
    }

    /// g += coef_i * x_i for row i (gradient scatter).
    #[inline]
    pub fn axpy_row(&self, i: usize, coef: f64, g: &mut [f64]) {
        assert!(g.len() >= self.ncols);
        let (cols, vals) = self.row_raw(i);
        // SAFETY: constructors keep every colidx < ncols ≤ g.len().
        unsafe { crate::kernels::active().axpy_col(cols, vals, coef, g) }
    }

    /// Transpose product g = Xᵀ v.
    pub fn tmul_vec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.nrows);
        let mut g = vec![0.0; self.ncols];
        for i in 0..self.nrows {
            if v[i] != 0.0 {
                self.axpy_row(i, v[i], &mut g);
            }
        }
        g
    }

    /// Select a subset of rows (in order) into a new matrix — the example
    /// shard for node m in by-example splitting.
    pub fn select_rows(&self, rows: &[usize]) -> Csr {
        let mut rowptr = Vec::with_capacity(rows.len() + 1);
        let nnz: usize = rows.iter().map(|&i| self.row_nnz(i)).sum();
        let mut colidx = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        rowptr.push(0);
        for &i in rows {
            let (cols, vals) = self.row_raw(i);
            colidx.extend_from_slice(cols);
            values.extend_from_slice(vals);
            rowptr.push(colidx.len());
        }
        Csr {
            nrows: rows.len(),
            ncols: self.ncols,
            rowptr,
            colidx,
            values,
        }
    }

    /// Convert to CSC (feature-major) layout.
    pub fn to_csc(&self) -> Csc {
        let mut colcnt = vec![0usize; self.ncols];
        for &c in &self.colidx {
            colcnt[c as usize] += 1;
        }
        let mut colptr = Vec::with_capacity(self.ncols + 1);
        colptr.push(0usize);
        for c in &colcnt {
            colptr.push(colptr.last().unwrap() + c);
        }
        let mut rowidx = vec![0u32; self.nnz()];
        let mut values = vec![0.0; self.nnz()];
        let mut next = colptr.clone();
        for i in 0..self.nrows {
            let (cols, vals) = self.row_raw(i);
            for (c, v) in cols.iter().zip(vals.iter()) {
                let slot = next[*c as usize];
                rowidx[slot] = i as u32;
                values[slot] = *v;
                next[*c as usize] += 1;
            }
        }
        Csc {
            nrows: self.nrows,
            ncols: self.ncols,
            colptr,
            rowidx,
            values,
        }
    }

    pub fn storage_bytes(&self) -> usize {
        self.rowptr.len() * std::mem::size_of::<usize>()
            + self.colidx.len() * std::mem::size_of::<u32>()
            + self.values.len() * std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn small() -> Csr {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        Csr::from_rows(
            3,
            &[
                vec![(0, 1.0), (2, 2.0)],
                vec![(1, 3.0)],
                vec![(0, 4.0), (2, 5.0)],
            ],
        )
    }

    #[test]
    fn layout_and_row_access() {
        let m = small();
        assert_eq!(m.nnz(), 5);
        assert_eq!(m.row(0).collect::<Vec<_>>(), vec![(0, 1.0), (2, 2.0)]);
        assert_eq!(m.row_nnz(1), 1);
    }

    #[test]
    fn dot_and_mul() {
        let m = small();
        assert_eq!(m.dot_row(0, &[1.0, 2.0, 3.0]), 7.0);
        assert_eq!(m.mul_vec(&[1.0, 2.0, 3.0]), vec![7.0, 6.0, 19.0]);
    }

    #[test]
    fn tmul_known() {
        let m = small();
        assert_eq!(m.tmul_vec(&[1.0, 2.0, 3.0]), vec![13.0, 6.0, 17.0]);
    }

    #[test]
    fn select_rows_shard() {
        let m = small();
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.nrows, 2);
        assert_eq!(s.row(0).collect::<Vec<_>>(), vec![(0, 4.0), (2, 5.0)]);
    }

    #[test]
    fn csc_csr_roundtrip() {
        let m = small();
        let back = m.to_csc().to_csr();
        assert_eq!(m, back);
    }

    #[test]
    fn prop_roundtrip_csr_csc() {
        prop::check("csr->csc->csr identity", 40, |rng| {
            let (nr, nc) = (1 + rng.below(12), 1 + rng.below(12));
            let rows: Vec<Vec<(usize, f64)>> = (0..nr)
                .map(|_| {
                    prop::sparse_vec(rng, nc, 6, 2.0)
                })
                .collect();
            let m = Csr::from_rows(nc, &rows);
            if m.to_csc().to_csr() == m {
                Ok(())
            } else {
                Err("roundtrip mismatch".into())
            }
        });
    }

    #[test]
    fn prop_tmul_agrees_with_csc() {
        prop::check("csr tmul = csc tmul", 40, |rng| {
            let (nr, nc) = (1 + rng.below(12), 1 + rng.below(12));
            let rows: Vec<Vec<(usize, f64)>> =
                (0..nr).map(|_| prop::sparse_vec(rng, nc, 6, 2.0)).collect();
            let m = Csr::from_rows(nc, &rows);
            let v = prop::dense_vec(rng, nr, 1.5);
            prop::all_close(&m.tmul_vec(&v), &m.to_csc().tmul_vec(&v), 1e-12)
        });
    }
}
