//! Sparse linear-algebra substrate: CSC/CSR matrices, libsvm IO, and the
//! feature/example partitioners that implement the paper's "vertical" and
//! "horizontal" data splits.

pub mod csc;
pub mod csr;
pub mod libsvm;
pub mod partition;

pub use csc::Csc;
pub use csr::Csr;
pub use partition::{ExamplePartition, FeaturePartition, PartitionStrategy};
