//! `dglmnet` — the launcher.
//!
//! Subcommands:
//!   train        train a regularized GLM on a synthetic corpus or libsvm file
//!   path         sweep a λ1 grid with warm starts + KKT screening, pick the
//!                validation-auPRC best (§8.2) — fabric, loopback TCP, or a
//!                real multi-process cluster (--cluster)
//!   convert      write a dataset as a binary columnar shard directory —
//!                `train --cluster --dataset shards:<dir>` then has each
//!                rank read only its own feature-block file (protocol v7)
//!   worker       serve one rank of a multi-process TCP cluster, then exit
//!   predict      score a libsvm file with a saved model (batch/offline)
//!   serve        online scoring endpoint with micro-batching and hot-swap
//!   bench-serve  load-generate against a serve endpoint (QPS, p50/p99)
//!   trace-report render timing breakdowns from a `--trace-out` run log
//!   summary      print the Table-1 style dataset summary
//!
//! Example (the end-to-end train → promote → serve story):
//!   dglmnet train --dataset clickstream --scale 0.5 --loss logistic \
//!       --l1 1.0 --nodes 8 --alb --max-iters 30 --save-model model.json
//!   dglmnet serve --model model.json --addr 127.0.0.1:7878
//!   dglmnet bench-serve --addr 127.0.0.1:7878 --threads 8
//!
//! Multi-process cluster (real sockets instead of the thread simulation;
//! start the workers first, then the coordinator — add --alb-kappa 0.75 for
//! asynchronous load balancing across the processes):
//!   dglmnet worker --listen 127.0.0.1:7101   # × M−1, one per node
//!   dglmnet train --cluster 127.0.0.1:7100,127.0.0.1:7101,... \
//!       --dataset epsilon_like --l1 1.0 --max-iters 30 --alb-kappa 0.75
//!
//! Hybrid parallelism (add to either shape): `--threads 4` splits every
//! rank's feature block across 4 pool threads — the cluster behaves like
//! M·4 blocks, same convergence theory, more of the box used.

// The launcher is the one place that talks to a human terminal directly:
// subcommand output and CLI errors go through plain println!/eprintln!.
// Library code must use `obs::log` (enforced by clippy's disallowed-macros).
#![allow(clippy::disallowed_macros)]

use std::sync::Arc;

use dglmnet::cluster::allreduce::AllReduceAlgo;
use dglmnet::cluster::process::{self, JobMode, JobSpec};
use dglmnet::coordinator::{
    fit_distributed, fit_path_distributed, fit_path_distributed_tcp, DistributedConfig,
};
use dglmnet::glm::loss::LossKind;
use dglmnet::glm::regularizer::ElasticNet;
use dglmnet::harness;
use dglmnet::metrics;
use dglmnet::glm::GlmModel;
use dglmnet::runtime::{Runtime, RuntimeHandle, XlaCompute};
use dglmnet::serve::{
    run_loadgen, serve, synthetic_model, BatcherConfig, ComputeFactory, LoadgenConfig,
    ModelRegistry, NativeFactory, Scorer, ServerConfig,
};
use dglmnet::solver::compute::{GlmCompute, NativeCompute};
use dglmnet::sparse::{libsvm, PartitionStrategy};
use dglmnet::util::bench::Table;
use dglmnet::util::cli::{Cli, CliError};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            usage();
            std::process::exit(2);
        }
    };
    let code = match cmd {
        "train" => cmd_train(&rest),
        "path" => cmd_path(&rest),
        "convert" => cmd_convert(&rest),
        "worker" => cmd_worker(&rest),
        "predict" => cmd_predict(&rest),
        "serve" => cmd_serve(&rest),
        "bench-serve" => cmd_bench_serve(&rest),
        "trace-report" => cmd_trace_report(&rest),
        "summary" => cmd_summary(&rest),
        "--help" | "-h" | "help" => {
            usage();
            0
        }
        other => {
            eprintln!("unknown subcommand '{other}'");
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "dglmnet — distributed coordinate descent for regularized GLMs\n\n\
         Subcommands:\n  \
         train        train a model (see `dglmnet train --help`)\n  \
         path         λ1-grid sweep with warm starts + KKT screening (§8.2)\n  \
         convert      write a dataset as a binary columnar shard directory \
         (out-of-core cluster ingestion)\n  \
         worker       serve one rank of a multi-process TCP cluster\n  \
         predict      score a libsvm file with a saved model\n  \
         serve        online scoring endpoint (micro-batched, hot-swappable)\n  \
         bench-serve  load-generate against a serve endpoint\n  \
         trace-report render per-iteration/per-rank timing from a --trace-out run log\n  \
         summary      print dataset summaries (Table 1)\n"
    );
}

fn train_cli() -> Cli {
    Cli::new(
        "dglmnet train",
        "train a regularized GLM with distributed coordinate descent",
    )
    .flag("dataset", "clickstream", "epsilon_like | webspam_like | clickstream | path to .libsvm")
    .flag("scale", "0.25", "synthetic corpus scale factor")
    .flag("loss", "logistic", "logistic | squared | probit")
    .flag("l1", "1.0", "L1 penalty λ1")
    .flag("l2", "0.0", "L2 penalty λ2")
    .flag("nodes", "8", "number of simulated cluster nodes M")
    .flag(
        "cluster",
        "",
        "comma-separated host:port list for a real multi-process TCP cluster \
         (entry 0 = this coordinator's listen address; others must be running \
         `dglmnet worker`). Overrides --nodes; BSP and ALB (--alb-kappa) both work",
    )
    .switch("alb", "enable Asynchronous Load Balancing (κ = 0.75)")
    .flag("kappa", "0.75", "ALB quorum fraction")
    .flag(
        "alb-kappa",
        "",
        "enable ALB with this quorum fraction κ in one flag (works with \
         --cluster: the asynchronous path runs across real processes)",
    )
    .flag("max-passes", "4", "ALB cap on full passes a fast node runs per iteration")
    .flag("chunk", "64", "coordinates between ALB quorum polls / straggler sleeps")
    .flag(
        "threads",
        "1",
        "intra-rank CD threads T (hybrid mode): each rank splits its feature \
         block into T sub-blocks run by a scoped pool — the cluster behaves \
         like M·T blocks. With --cluster a comma list assigns one count per \
         rank",
    )
    .flag(
        "straggler-delays-ms",
        "",
        "comma list of injected per-pass delays in ms, one per rank \
         (deterministic slow-node chaos; shipped to workers via the job spec)",
    )
    .flag(
        "slow-factors",
        "",
        "comma list of per-rank compute handicaps for the virtual clock \
         (requires --virtual-time)",
    )
    .switch(
        "virtual-time",
        "trace timestamps = max-over-ranks CPU time (× --slow-factors) + \
         modeled wire time, instead of wall-clock",
    )
    .flag(
        "partition",
        "",
        "feature→block strategy: hashed (default) | contiguous | nnz \
         (balances nonzeros) | cluster (co-occurrence clustering — groups \
         correlated features on one rank). A shards:<dir> dataset pins the \
         strategy its converter used",
    )
    .flag("engine", "native", "compute engine: native | xla (needs artifacts/)")
    .flag("artifacts", "artifacts", "artifacts directory for --engine xla")
    .flag("max-iters", "50", "outer iteration budget")
    .flag("mu0", "1.0", "initial trust-region μ")
    .switch("no-adaptive-mu", "freeze μ at --mu0 (Fig 1 ablation)")
    .flag("seed", "1", "random seed")
    .flag("trace", "", "write the convergence trace JSON to this path")
    .flag(
        "trace-out",
        "",
        "write the merged run log (run header + per-rank loads + spans) as \
         NDJSON to this path; render it with `dglmnet trace-report`",
    )
    .flag(
        "log-level",
        "",
        "structured-log verbosity: error | warn | info | debug | trace \
         (default: DGLMNET_LOG env, else info)",
    )
    .flag("save-model", "", "write the trained model JSON to this path")
    .flag("eval-every", "1", "test-metric cadence (0 = never)")
    .flag(
        "checkpoint-dir",
        "",
        "persist per-iteration checkpoints under this directory (written by \
         rank 0). With --cluster, a job that loses a rank resumes \
         automatically from the latest complete checkpoint across the \
         surviving workers",
    )
    .flag(
        "checkpoint-every",
        "",
        "checkpoint every k-th outer iteration (default 1 when \
         --checkpoint-dir is set; 0 disables)",
    )
    .switch(
        "resume",
        "with --cluster: start from the latest complete checkpoint under \
         --checkpoint-dir instead of from zero",
    )
    .switch(
        "fast-math",
        "reordered-accumulation kernels: faster reductions at the cost of \
         bit-reproducibility (results stay within the documented fast-math \
         tolerance tier); with --cluster the flag rides in the v9 job spec \
         so every rank runs the same kernels",
    )
}

/// Apply a `--log-level` value to the global `obs::log` filter. Empty means
/// "leave it to `DGLMNET_LOG` / the default"; a bad name is a usage error.
fn apply_log_level(value: &str) -> Result<(), String> {
    if value.is_empty() {
        return Ok(());
    }
    match dglmnet::obs::log::Level::parse(value) {
        Some(lvl) => {
            dglmnet::obs::log::set_level(lvl);
            Ok(())
        }
        None => Err(format!(
            "unknown log level '{value}' (error | warn | info | debug | trace)"
        )),
    }
}

fn cmd_train(argv: &[String]) -> i32 {
    let cli = train_cli();
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(CliError::HelpRequested) => {
            println!("{}", cli.help_text());
            return 0;
        }
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cli.help_text());
            return 2;
        }
    };

    if let Err(e) = apply_log_level(args.get("log-level")) {
        eprintln!("{e}");
        return 2;
    }
    let kind = match LossKind::parse(args.get("loss")) {
        Some(k) => k,
        None => {
            eprintln!("unknown loss '{}'", args.get("loss"));
            return 2;
        }
    };
    let scale = args.get_f64("scale");
    let seed = args.get_u64("seed");
    let pen = ElasticNet::new(args.get_f64("l1"), args.get_f64("l2"));
    let cluster: Vec<String> = if args.get("cluster").is_empty() {
        Vec::new()
    } else {
        args.get("cluster")
            .split(',')
            .map(|s| s.trim().to_string())
            .collect()
    };
    if !cluster.is_empty() {
        if cluster.len() < 2 {
            eprintln!("--cluster needs at least two addresses (coordinator first, then workers)");
            return 2;
        }
        if cluster.iter().any(|a| a.is_empty()) {
            eprintln!("--cluster contains an empty address (stray comma?)");
            return 2;
        }
        if args.get("engine") != "native" {
            eprintln!("--cluster currently supports --engine native only");
            return 2;
        }
    }
    // Out-of-core ingestion (protocol v7): with --cluster and a shards:<dir>
    // recipe, the coordinator never materializes the full matrix — each rank
    // (rank 0 included) reads only its own feature-block file inside
    // train_cluster. Banner dims and the final test scoring come from the
    // shard header and the test row shard instead. Without --cluster,
    // load_splits reassembles the directory in-process.
    let out_of_core =
        !cluster.is_empty() && dglmnet::data::shards::shard_recipe(args.get("dataset")).is_some();
    let splits = if out_of_core {
        None
    } else {
        match harness::load_splits(args.get("dataset"), scale, seed) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("dataset error: {e}");
                return 2;
            }
        }
    };
    let mut shard_test: Option<dglmnet::data::Dataset> = None;
    let mut shard_kind: Option<PartitionStrategy> = None;
    let (ds_name, n, p, nnz) = match &splits {
        Some(s) => (s.train.name.clone(), s.train.n(), s.train.p(), s.train.nnz()),
        None => {
            let dir_str = dglmnet::data::shards::shard_recipe(args.get("dataset"))
                .expect("out_of_core implies a shards: recipe");
            let dir = std::path::Path::new(dir_str);
            let header = match dglmnet::data::shards::open_header(dir) {
                Ok(h) => h,
                Err(e) => {
                    eprintln!("dataset error: {e}");
                    return 2;
                }
            };
            if header.num_blocks() != cluster.len() {
                eprintln!(
                    "shard directory {} holds {} feature blocks but --cluster names {} ranks — \
                     re-run `dglmnet convert ... --blocks {}`",
                    dir.display(),
                    header.num_blocks(),
                    cluster.len(),
                    cluster.len(),
                );
                return 2;
            }
            match header.load_rows(dir, "test") {
                Ok((t, _)) => shard_test = Some(t),
                Err(e) => {
                    eprintln!("dataset error: {e}");
                    return 2;
                }
            }
            shard_kind = Some(header.kind);
            (format!("{}-train", header.name), header.n, header.p, header.nnz)
        }
    };
    // ALB selection: --alb-kappa κ in one flag, or the --alb switch with
    // the separate --kappa fraction. Either form works with --cluster (the
    // per-iteration quorum needs no shared memory).
    let alb_kappa = if !args.get("alb-kappa").is_empty() {
        match args.get("alb-kappa").parse::<f64>() {
            Ok(k) => Some(k),
            Err(_) => {
                eprintln!("--alb-kappa must be a number in (0, 1]");
                return 2;
            }
        }
    } else if args.get_bool("alb") {
        Some(args.get_f64("kappa"))
    } else {
        None
    };
    // Validated once for both spellings (--alb-kappa and --alb --kappa):
    // an out-of-range κ must be a usage error, not a quorum assert later.
    if let Some(k) = alb_kappa {
        if !(k > 0.0 && k <= 1.0) {
            eprintln!("ALB quorum fraction must be in (0, 1], got {k}");
            return 2;
        }
    }
    let straggler_delays = match parse_f64_list(args.get("straggler-delays-ms")) {
        // bounded_delay: stay out of `Duration::from_secs_f64`'s panic
        // domain even for absurd-but-finite values.
        Ok(ms) => ms
            .into_iter()
            .map(|m| process::bounded_delay(m / 1000.0))
            .collect::<Vec<_>>(),
        Err(e) => {
            eprintln!("--straggler-delays-ms: {e}");
            return 2;
        }
    };
    let slow_factors = match parse_f64_list(args.get("slow-factors")) {
        Ok(fs) => {
            if fs.iter().any(|f| *f <= 0.0) {
                eprintln!("--slow-factors entries must be positive");
                return 2;
            }
            fs
        }
        Err(e) => {
            eprintln!("--slow-factors: {e}");
            return 2;
        }
    };
    let virtual_time = args.get_bool("virtual-time");
    if !slow_factors.is_empty() && !virtual_time {
        eprintln!("--slow-factors only scale the virtual clock; add --virtual-time");
        return 2;
    }
    let threads = match parse_threads_list(args.get("threads"), cluster.len()) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("--threads: {e}");
            return 2;
        }
    };
    if virtual_time && threads.iter().any(|&t| t > 1) {
        eprintln!(
            "--virtual-time charges per-thread CPU time and cannot account \
             hybrid pool compute yet; drop --threads or --virtual-time"
        );
        return 2;
    }
    let checkpoint_dir = if args.get("checkpoint-dir").is_empty() {
        None
    } else {
        Some(args.get("checkpoint-dir").to_string())
    };
    let checkpoint_every = if args.get("checkpoint-every").is_empty() {
        usize::from(checkpoint_dir.is_some())
    } else {
        match args.get("checkpoint-every").parse::<usize>() {
            Ok(k) if k <= process::MAX_CHECKPOINT_EVERY => k,
            _ => {
                eprintln!(
                    "--checkpoint-every must be an integer in [0, {}]",
                    process::MAX_CHECKPOINT_EVERY
                );
                return 2;
            }
        }
    };
    if checkpoint_every > 0 && checkpoint_dir.is_none() {
        eprintln!("--checkpoint-every needs --checkpoint-dir");
        return 2;
    }
    let resume = args.get_bool("resume");
    if resume && checkpoint_dir.is_none() {
        eprintln!("--resume needs --checkpoint-dir");
        return 2;
    }
    if resume && cluster.is_empty() {
        eprintln!("--resume needs --cluster (in-process runs always start from zero)");
        return 2;
    }
    // Kernel mode: set the process-global pin here for in-process runs; the
    // cluster path re-pins every rank (this one included) from the v9 job
    // spec inside solve_rank, so both routes agree.
    let fast_math = args.get_bool("fast-math");
    dglmnet::kernels::set_fast_math(fast_math);
    // Partition strategy: empty = unset, which keeps the historical layout
    // (hashed for text datasets, header-pinned for shards).
    let partition_flag = match args.get("partition") {
        "" => None,
        name => match PartitionStrategy::parse(name) {
            Some(s) => Some(s),
            None => {
                eprintln!(
                    "unknown --partition '{name}' (hashed | contiguous | nnz | cluster)"
                );
                return 2;
            }
        },
    };
    let cfg = DistributedConfig {
        nodes: if cluster.is_empty() {
            args.get_usize("nodes")
        } else {
            cluster.len()
        },
        alb_kappa,
        adaptive_mu: !args.get_bool("no-adaptive-mu"),
        mu0: args.get_f64("mu0"),
        max_iters: args.get_usize("max-iters"),
        eval_every: args.get_usize("eval-every"),
        seed,
        allreduce: AllReduceAlgo::Ring,
        max_passes: args.get_usize("max-passes"),
        chunk: args.get_usize("chunk"),
        threads: threads[0],
        straggler_delays: straggler_delays.clone(),
        virtual_time,
        slow_factors: slow_factors.clone(),
        checkpoint_dir: checkpoint_dir.clone(),
        checkpoint_every,
        partition: partition_flag.unwrap_or_default(),
        ..Default::default()
    };

    println!(
        "train: dataset={} n={} p={} nnz={} | loss={} λ1={} λ2={} | M={} T={} alb={} engine={} kernels={}",
        ds_name,
        n,
        p,
        nnz,
        kind.name(),
        pen.l1,
        pen.l2,
        cfg.nodes,
        threads.iter().max().copied().unwrap_or(1),
        cfg.alb_kappa.is_some(),
        args.get("engine"),
        if fast_math { "fast-math" } else { "strict" },
    );
    // The effective strategy line the e2e gates grep for: a shards dataset
    // pins its header's kind regardless of the flag (a conflicting flag
    // errors out inside ingestion).
    let effective_partition = shard_kind.unwrap_or(partition_flag.unwrap_or_default());
    println!(
        "partition: strategy={}{}",
        effective_partition.name(),
        if shard_kind.is_some() { " (pinned by shard header)" } else { "" },
    );

    // Backend selection: a real multi-process TCP cluster when --cluster is
    // given; otherwise the in-process fabric with the chosen compute engine
    // (the XLA runtime executes the AOT Pallas artifacts on the hot path;
    // native is the pure-Rust oracle).
    let result = if !cluster.is_empty() {
        let spec = JobSpec {
            rank: 0,
            cluster,
            dataset: args.get("dataset").to_string(),
            scale,
            seed,
            loss: args.get("loss").to_string(),
            l1: pen.l1,
            l2: pen.l2,
            max_iters: cfg.max_iters,
            mu0: cfg.mu0,
            adaptive_mu: cfg.adaptive_mu,
            tol: cfg.tol,
            patience: cfg.patience,
            eval_every: cfg.eval_every,
            allreduce: AllReduceAlgo::Ring,
            alb_kappa: cfg.alb_kappa,
            max_passes: cfg.max_passes,
            chunk: cfg.chunk,
            straggler_delays: straggler_delays
                .iter()
                .map(|d| d.as_secs_f64())
                .collect(),
            virtual_time: cfg.virtual_time,
            slow_factors,
            mode: JobMode::Train,
            lambda_grid: Vec::new(),
            screen: false,
            threads: threads.clone(),
            checkpoint_dir: checkpoint_dir.clone(),
            checkpoint_every,
            resume,
            partition: partition_flag,
            fast_math,
        };
        match process::train_cluster(&spec, splits.as_ref()) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("cluster training failed: {e}");
                return 1;
            }
        }
    } else {
        let s = splits.as_ref().expect("non-cluster runs materialize the splits");
        match args.get("engine") {
            "xla" => {
                let rt = match Runtime::start(args.get("artifacts")) {
                    Ok(rt) => rt,
                    Err(e) => {
                        eprintln!(
                            "failed to start XLA runtime: {e}\n(build artifacts with `make artifacts`)"
                        );
                        return 1;
                    }
                };
                let compute = XlaCompute::new(rt.handle(), kind);
                fit_distributed(&s.train, Some(&s.test), &compute, &pen, &cfg)
            }
            "native" => {
                let compute = NativeCompute::new(kind);
                fit_distributed(&s.train, Some(&s.test), &compute, &pen, &cfg)
            }
            other => {
                eprintln!("unknown engine '{other}'");
                return 2;
            }
        }
    };

    let test: &dglmnet::data::Dataset = match (&shard_test, &splits) {
        (Some(t), _) => t,
        (None, Some(s)) => &s.test,
        (None, None) => unreachable!("either the splits or the shard test rows exist"),
    };
    let scores = test.x.mul_vec(&result.beta);
    let auprc = metrics::auprc(&test.y, &scores);
    let auc = metrics::roc_auc(&test.y, &scores);
    println!(
        "\ndone: iters={} objective={:.6} nnz={}/{} test auPRC={:.4} ROC-AUC={:.4}",
        result.iters,
        result.objective,
        metrics::nnz_weights(&result.beta),
        result.beta.len(),
        auprc,
        auc
    );
    println!(
        "comm: {:.2} MiB in {} messages (modeled wire time {:.3}s) | sync wait {:.3}s | peak node mem {:.1} MiB",
        result.comm_bytes as f64 / (1024.0 * 1024.0),
        result.comm_msgs,
        result.sim_wire_secs,
        result.barrier_wait_secs,
        result.peak_node_f64_slots as f64 * 8.0 / (1024.0 * 1024.0),
    );
    if !result.comm_by_phase.is_empty() {
        let parts: Vec<String> = result
            .comm_by_phase
            .iter()
            .map(|(phase, bytes, msgs)| {
                format!("{phase} {:.2} MiB/{msgs} msgs", *bytes as f64 / (1024.0 * 1024.0))
            })
            .collect();
        println!("comm by tag: {}", parts.join(" | "));
    }
    harness::print_rank_loads(&result.per_rank);
    harness::print_convergence(&ds_name, &[&result.trace], result.trace.best_objective());

    let trace_path = args.get("trace");
    if !trace_path.is_empty() {
        if let Err(e) = std::fs::write(trace_path, result.trace.to_json().dump()) {
            eprintln!("failed to write trace: {e}");
            return 1;
        }
        println!("trace written to {trace_path}");
    }
    let trace_out = args.get("trace-out");
    if !trace_out.is_empty() {
        let mut header = dglmnet::util::json::Json::obj();
        header
            .set("dataset", ds_name.as_str())
            .set("nodes", cfg.nodes)
            .set("iters", result.iters)
            .set("comm_bytes", result.comm_bytes)
            .set("comm_msgs", result.comm_msgs);
        let ranks: Vec<_> = result.per_rank.iter().map(|r| r.to_json()).collect();
        let body = dglmnet::obs::runlog::render(&header, &ranks, &result.spans);
        if let Err(e) = std::fs::write(trace_out, body) {
            eprintln!("failed to write run log: {e}");
            return 1;
        }
        println!(
            "run log written to {trace_out} ({} spans from {} ranks); \
             render with `dglmnet trace-report {trace_out}`",
            result.spans.len(),
            result.per_rank.len(),
        );
    }
    let model_path = args.get("save-model");
    if !model_path.is_empty() {
        let model = dglmnet::glm::GlmModel::new(kind, result.beta.clone())
            .with_meta("dataset", &ds_name)
            .with_meta("l1", pen.l1)
            .with_meta("l2", pen.l2)
            .with_meta("nodes", cfg.nodes);
        if let Err(e) = model.save(model_path) {
            eprintln!("failed to save model: {e}");
            return 1;
        }
        println!("model written to {model_path} ({} non-zero weights)", model.nnz());
    }
    0
}

fn path_cli() -> Cli {
    Cli::new(
        "dglmnet path",
        "sweep a descending λ1 grid with warm starts and KKT strong-rule \
         screening; select the validation-auPRC best point (paper §8.2)",
    )
    .flag("dataset", "clickstream", "epsilon_like | webspam_like | clickstream | path to .libsvm")
    .flag("scale", "0.25", "synthetic corpus scale factor")
    .flag("loss", "logistic", "logistic | squared | probit")
    .flag(
        "lambdas",
        "paper",
        "comma-separated λ1 grid (descending for warm starts to pay off), \
         or 'paper' for the §8.2 grid {2⁶, …, 2⁻⁶}",
    )
    .flag("l2", "0.0", "fixed L2 penalty λ2 held constant along the path")
    .flag("nodes", "8", "simulated cluster width M (ignored with --cluster)")
    .flag(
        "cluster",
        "",
        "comma-separated host:port list for a real multi-process TCP sweep \
         (entry 0 = this coordinator's listen address; others must be running \
         `dglmnet worker`). Overrides --nodes; ships a job-spec v3 path job",
    )
    .flag(
        "transport",
        "fabric",
        "single-process backend: fabric (in-process) | tcp (loopback socket mesh)",
    )
    .switch("no-screen", "disable KKT screening (cycle every coordinate at every λ)")
    .flag(
        "threads",
        "1",
        "intra-rank CD threads T (hybrid mode) for the sweep's screened \
         passes; with --cluster a comma list assigns one count per rank",
    )
    .flag(
        "partition",
        "",
        "feature→block strategy: hashed (default) | contiguous | nnz | \
         cluster (co-occurrence clustering)",
    )
    .flag("max-iters", "100", "outer iteration budget per λ point")
    .flag("seed", "1", "random seed")
    .flag("save-model", "", "write the validation-best model JSON to this path")
    .switch(
        "fast-math",
        "reordered-accumulation kernels: faster reductions at the cost of \
         bit-reproducibility (results stay within the documented fast-math \
         tolerance tier); with --cluster the flag rides in the v9 job spec \
         so every rank runs the same kernels",
    )
}

fn cmd_path(argv: &[String]) -> i32 {
    let cli = path_cli();
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(CliError::HelpRequested) => {
            println!("{}", cli.help_text());
            return 0;
        }
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cli.help_text());
            return 2;
        }
    };

    let kind = match LossKind::parse(args.get("loss")) {
        Some(k) => k,
        None => {
            eprintln!("unknown loss '{}'", args.get("loss"));
            return 2;
        }
    };
    let scale = args.get_f64("scale");
    let seed = args.get_u64("seed");
    let splits = match harness::load_splits(args.get("dataset"), scale, seed) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("dataset error: {e}");
            return 2;
        }
    };
    let l2 = args.get_f64("l2");
    let lambdas: Vec<f64> = if args.get("lambdas") == "paper" {
        dglmnet::solver::path::paper_lambda_grid()
    } else {
        match parse_f64_list(args.get("lambdas")) {
            Ok(ls) if !ls.is_empty() && ls.iter().all(|l| l.is_finite() && *l > 0.0) => ls,
            Ok(_) => {
                eprintln!("--lambdas needs a non-empty list of positive finite values (or 'paper')");
                return 2;
            }
            Err(e) => {
                eprintln!("--lambdas: {e}");
                return 2;
            }
        }
    };
    let screen = !args.get_bool("no-screen");
    let cluster: Vec<String> = if args.get("cluster").is_empty() {
        Vec::new()
    } else {
        args.get("cluster")
            .split(',')
            .map(|s| s.trim().to_string())
            .collect()
    };
    if !cluster.is_empty() {
        if cluster.len() < 2 {
            eprintln!("--cluster needs at least two addresses (coordinator first, then workers)");
            return 2;
        }
        if cluster.iter().any(|a| a.is_empty()) {
            eprintln!("--cluster contains an empty address (stray comma?)");
            return 2;
        }
    }
    let nodes = if cluster.is_empty() {
        args.get_usize("nodes")
    } else {
        cluster.len()
    };
    let threads = match parse_threads_list(args.get("threads"), cluster.len()) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("--threads: {e}");
            return 2;
        }
    };
    let partition_flag = match args.get("partition") {
        "" => None,
        name => match PartitionStrategy::parse(name) {
            Some(s) => Some(s),
            None => {
                eprintln!(
                    "unknown --partition '{name}' (hashed | contiguous | nnz | cluster)"
                );
                return 2;
            }
        },
    };
    // Same pin-here-and-in-the-spec pattern as cmd_train.
    let fast_math = args.get_bool("fast-math");
    dglmnet::kernels::set_fast_math(fast_math);

    println!(
        "path: dataset={} n={} p={} nnz={} | loss={} λ2={} | {} λ1 points [{} .. {}] | M={} screening={}",
        splits.train.name,
        splits.train.n(),
        splits.train.p(),
        splits.train.nnz(),
        kind.name(),
        l2,
        lambdas.len(),
        lambdas.first().unwrap(),
        lambdas.last().unwrap(),
        nodes,
        screen,
    );
    println!(
        "partition: strategy={}",
        partition_flag.unwrap_or_default().name()
    );

    let result = if !cluster.is_empty() {
        let spec = JobSpec {
            rank: 0,
            cluster,
            dataset: args.get("dataset").to_string(),
            scale,
            seed,
            loss: args.get("loss").to_string(),
            l1: 0.0, // path mode: the grid supplies λ1
            l2,
            max_iters: args.get_usize("max-iters"),
            mu0: 1.0,
            adaptive_mu: true,
            tol: 1e-7,
            patience: 2,
            eval_every: 0,
            allreduce: AllReduceAlgo::Ring,
            alb_kappa: None,
            max_passes: 1,
            chunk: 64,
            straggler_delays: Vec::new(),
            virtual_time: false,
            slow_factors: Vec::new(),
            mode: JobMode::Path,
            lambda_grid: lambdas.clone(),
            screen,
            threads: threads.clone(),
            checkpoint_dir: None,
            checkpoint_every: 0,
            resume: false,
            partition: partition_flag,
            fast_math,
        };
        match process::path_cluster(&spec, Some(&splits)) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("cluster path sweep failed: {e}");
                return 1;
            }
        }
    } else {
        let cfg = DistributedConfig {
            nodes,
            max_iters: args.get_usize("max-iters"),
            eval_every: 0,
            seed,
            allreduce: AllReduceAlgo::Ring,
            threads: threads[0],
            partition: partition_flag.unwrap_or_default(),
            ..Default::default()
        };
        let compute = NativeCompute::new(kind);
        let sweep = match args.get("transport") {
            "fabric" => fit_path_distributed(&splits, &compute, &lambdas, l2, &cfg, screen),
            "tcp" => fit_path_distributed_tcp(&splits, &compute, &lambdas, l2, &cfg, screen),
            other => {
                eprintln!("unknown transport '{other}' (fabric | tcp)");
                return 2;
            }
        };
        match sweep {
            Ok(r) => r,
            Err(e) => {
                eprintln!("path sweep failed: {e}");
                return 1;
            }
        }
    };

    harness::print_path_table(&result.path);
    let best = result.path.best_point();
    let scores = splits.test.x.mul_vec(&best.beta);
    println!(
        "\nbest: λ1={} λ2={} | objective={:.6} nnz={}/{} | val auPRC={:.4} test auPRC={:.4} | total cd updates={}",
        best.lambda1,
        best.lambda2,
        best.objective,
        best.nnz,
        best.beta.len(),
        best.val_auprc,
        metrics::auprc(&splits.test.y, &scores),
        result.path.total_cd_updates(),
    );
    println!(
        "comm: {:.2} MiB in {} messages",
        result.comm_bytes as f64 / (1024.0 * 1024.0),
        result.comm_msgs,
    );

    let model_path = args.get("save-model");
    if !model_path.is_empty() {
        let model = dglmnet::glm::GlmModel::new(kind, best.beta.clone())
            .with_meta("dataset", &splits.train.name)
            .with_meta("l1", best.lambda1)
            .with_meta("l2", best.lambda2);
        if let Err(e) = model.save(model_path) {
            eprintln!("failed to save model: {e}");
            return 1;
        }
        println!("model written to {model_path} ({} non-zero weights)", model.nnz());
    }
    0
}

fn convert_cli() -> Cli {
    Cli::new(
        "dglmnet convert",
        "write a dataset as a binary columnar shard directory (checksummed \
         header + one CSC feature-block file per rank + shared label and \
         row shards; see DESIGN.md §Shard format). A cluster trained with \
         `--dataset shards:<dir>` has each rank read only its own block",
    )
    .flag(
        "dataset",
        "",
        "epsilon_like | webspam_like | clickstream | path to .libsvm \
         (may also be given positionally: `dglmnet convert data.libsvm ...`)",
    )
    .required("out", "output shard directory (created; files are written atomically)")
    .flag(
        "blocks",
        "8",
        "number of feature blocks M — must equal the rank count of any \
         cluster that trains from this directory",
    )
    .flag(
        "partition",
        "hashed",
        "feature→block assignment: hashed (matches the text cluster path \
         bit-for-bit) | contiguous | nnz (balances nonzeros) | cluster \
         (co-occurrence clustering — groups correlated features per block)",
    )
    .flag("scale", "0.25", "synthetic corpus scale factor")
    .flag("seed", "1", "random seed (corpus generation + hashed partition)")
}

fn cmd_convert(argv: &[String]) -> i32 {
    let cli = convert_cli();
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(CliError::HelpRequested) => {
            println!("{}", cli.help_text());
            return 0;
        }
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cli.help_text());
            return 2;
        }
    };
    let dataset = if !args.get("dataset").is_empty() {
        args.get("dataset").to_string()
    } else if let Some(first) = args.positional().first() {
        first.clone()
    } else {
        eprintln!(
            "usage: dglmnet convert <dataset> --out <dir> [--blocks M] [--partition kind]\n\n{}",
            cli.help_text()
        );
        return 2;
    };
    let kind = match dglmnet::data::shards::PartitionKind::parse(args.get("partition")) {
        Some(k) => k,
        None => {
            eprintln!(
                "unknown --partition '{}' (hashed | contiguous | nnz | cluster)",
                args.get("partition")
            );
            return 2;
        }
    };
    let out = std::path::Path::new(args.get("out"));
    let report = dglmnet::data::shards::convert_recipe(
        &dataset,
        args.get_f64("scale"),
        args.get_u64("seed"),
        args.get_usize("blocks"),
        kind,
        out,
    );
    match report {
        Ok(rep) => {
            println!(
                "convert: dataset={} n={} p={} nnz={} -> {} | {} blocks ({} partition), \
                 {} files, {:.1} MiB",
                rep.name,
                rep.n,
                rep.p,
                rep.nnz,
                out.display(),
                rep.blocks,
                rep.kind.name(),
                rep.write.files,
                rep.write.bytes as f64 / (1024.0 * 1024.0),
            );
            let cols: Vec<String> = rep
                .write
                .block_cols
                .iter()
                .zip(rep.write.block_nnz.iter())
                .enumerate()
                .map(|(r, (c, z))| format!("{r}:{c}c/{z}nz"))
                .collect();
            println!("blocks: {}", cols.join(" "));
            println!(
                "train from it with: dglmnet train --cluster <{} addrs> --dataset shards:{}",
                rep.blocks,
                out.display(),
            );
            0
        }
        Err(e) => {
            eprintln!("convert failed: {e}");
            1
        }
    }
}

fn cmd_worker(argv: &[String]) -> i32 {
    let cli = Cli::new(
        "dglmnet worker",
        "serve one rank of a multi-process TCP training cluster, then exit \
         (rank, data recipe, and hyper-parameters arrive from the coordinator)",
    )
    .flag("listen", "127.0.0.1:0", "listen address for control + cluster mesh (port 0 = ephemeral, printed on startup)")
    .flag(
        "slow-factor",
        "",
        "override this rank's virtual-clock compute handicap (takes effect \
         when the coordinator's job enables --virtual-time)",
    )
    .flag(
        "straggler-delay-ms",
        "",
        "override this rank's injected per-pass delay in ms (local chaos injection)",
    )
    .flag(
        "threads",
        "",
        "override this rank's intra-rank CD thread count (hybrid mode) — \
         right-size one node to its cores without the coordinator's help",
    )
    .switch(
        "rejoin",
        "keep serving after a job dies of peer loss: stay on the same \
         listen address, answer the coordinator's liveness probes, and \
         accept the re-shipped resume job (protocol v6)",
    )
    .flag(
        "die-after",
        "",
        "chaos injection: crash this rank right after the k-th outer \
         iteration (drops the mesh, peers observe a hang-up) — drives the \
         fault-tolerance tests without an external kill",
    )
    .flag(
        "fast-math",
        "",
        "pin this rank's kernel tier: 'on' (fast-math only) or 'off' \
         (strict only). A job spec that disagrees is rejected with a \
         pointed error instead of silently mixing kernel tiers across the \
         cluster; unset = follow whatever the job spec says (protocol v9)",
    )
    .flag(
        "log-level",
        "",
        "structured-log verbosity: error | warn | info | debug | trace \
         (default: DGLMNET_LOG env, else info)",
    );
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(CliError::HelpRequested) => {
            println!("{}", cli.help_text());
            return 0;
        }
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cli.help_text());
            return 2;
        }
    };
    if let Err(e) = apply_log_level(args.get("log-level")) {
        eprintln!("{e}");
        return 2;
    }
    let mut overrides = process::WorkerOverrides::default();
    if !args.get("slow-factor").is_empty() {
        match args.get("slow-factor").parse::<f64>() {
            Ok(f) if f.is_finite() && f > 0.0 => overrides.slow_factor = Some(f),
            _ => {
                eprintln!("--slow-factor must be a positive number");
                return 2;
            }
        }
    }
    if !args.get("straggler-delay-ms").is_empty() {
        match args.get("straggler-delay-ms").parse::<f64>() {
            // bounded_delay keeps even absurd finite values out of
            // `Duration::from_secs_f64`'s panic domain.
            Ok(ms) if ms.is_finite() && ms >= 0.0 => {
                overrides.straggler_delay = Some(process::bounded_delay(ms / 1000.0));
            }
            _ => {
                eprintln!("--straggler-delay-ms must be a non-negative number");
                return 2;
            }
        }
    }
    if !args.get("threads").is_empty() {
        match args.get("threads").parse::<usize>() {
            Ok(t) if process::thread_count_in_range(t) => overrides.threads = Some(t),
            _ => {
                eprintln!(
                    "--threads must be an integer in [1, {}]",
                    process::MAX_THREADS_PER_RANK
                );
                return 2;
            }
        }
    }
    if !args.get("die-after").is_empty() {
        match args.get("die-after").parse::<usize>() {
            Ok(k) => overrides.die_after_iters = Some(k),
            Err(_) => {
                eprintln!("--die-after must be a non-negative integer");
                return 2;
            }
        }
    }
    match args.get("fast-math") {
        "" => {}
        "on" => overrides.fast_math = Some(true),
        "off" => overrides.fast_math = Some(false),
        other => {
            eprintln!("--fast-math must be 'on' or 'off', got '{other}'");
            return 2;
        }
    }
    match process::run_worker_process(args.get("listen"), overrides, args.get_bool("rejoin")) {
        Ok(_) => 0,
        Err(e) => {
            eprintln!("worker failed: {e}");
            1
        }
    }
}

/// Parse the --threads flag: a single count (applied uniformly) or, with
/// --cluster, a comma list assigning one count per rank. `m` is the cluster
/// size (0 = non-cluster mode: only a single count makes sense). Returns
/// one entry per rank (a single entry in non-cluster mode).
fn parse_threads_list(s: &str, m: usize) -> Result<Vec<usize>, String> {
    let entries = s
        .split(',')
        .map(|tok| {
            let tok = tok.trim();
            match tok.parse::<usize>() {
                Ok(t) if process::thread_count_in_range(t) => Ok(t),
                _ => Err(format!(
                    "bad entry '{tok}': expected an integer in [1, {}]",
                    process::MAX_THREADS_PER_RANK
                )),
            }
        })
        .collect::<Result<Vec<usize>, String>>()?;
    match (m, entries.len()) {
        (0, 1) => Ok(entries),
        (0, _) => Err("a per-rank thread list needs --cluster; give a single count".into()),
        (m, 1) => Ok(vec![entries[0]; m]),
        (m, k) if k == m => Ok(entries),
        (m, k) => Err(format!("{k} entries for a cluster of {m} ranks")),
    }
}

/// Parse a comma-separated list of numbers ("" → empty).
fn parse_f64_list(s: &str) -> Result<Vec<f64>, String> {
    if s.trim().is_empty() {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|tok| {
            let tok = tok.trim();
            tok.parse::<f64>()
                .map_err(|e| format!("bad entry '{tok}': {e}"))
                .and_then(|v| {
                    if v.is_finite() && v >= 0.0 {
                        Ok(v)
                    } else {
                        Err(format!("entry '{tok}' must be finite and non-negative"))
                    }
                })
        })
        .collect()
}

fn cmd_predict(argv: &[String]) -> i32 {
    let cli = Cli::new("dglmnet predict", "score a libsvm file with a saved model")
        .required("model", "path to a model JSON written by `train --save-model`")
        .required("data", "path to a libsvm file")
        .flag("out", "", "write probabilities here (default: stdout)")
        .switch("metrics", "labels are present: also print auPRC / ROC-AUC / logloss");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(CliError::HelpRequested) => {
            println!("{}", cli.help_text());
            return 0;
        }
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cli.help_text());
            return 2;
        }
    };
    let model = match dglmnet::glm::GlmModel::load(args.get("model")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("failed to load model: {e}");
            return 1;
        }
    };
    let data = match libsvm::read_file(args.get("data")) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("failed to read data: {e}");
            return 1;
        }
    };
    // Align feature space: re-read with the model width as a hint would be
    // cleaner, but padding the matrix is equivalent for prediction.
    if data.x.ncols > model.p {
        eprintln!(
            "data has {} features but model only {} — refusing to truncate",
            data.x.ncols, model.p
        );
        return 1;
    }
    let probs = model.predict_proba(&data.x);
    let out_path = args.get("out");
    let mut body = String::new();
    for p in &probs {
        body.push_str(&format!("{p}\n"));
    }
    if out_path.is_empty() {
        print!("{body}");
    } else if let Err(e) = std::fs::write(out_path, body) {
        eprintln!("failed to write predictions: {e}");
        return 1;
    }
    if args.get_bool("metrics") {
        println!(
            "auPRC {:.4}  ROC-AUC {:.4}  logloss {:.4}  (n = {})",
            metrics::auprc(&data.y, &probs),
            metrics::roc_auc(&data.y, &probs),
            metrics::logloss(&data.y, &probs),
            probs.len()
        );
    }
    0
}

/// `--engine xla` face of the serve-side compute split: builds an
/// [`XlaCompute`] per model version over one shared runtime.
struct XlaFactory {
    handle: RuntimeHandle,
}

impl ComputeFactory for XlaFactory {
    fn name(&self) -> &'static str {
        "xla"
    }
    fn build(&self, kind: LossKind) -> Box<dyn GlmCompute> {
        Box::new(XlaCompute::new(self.handle.clone(), kind))
    }
}

fn factory_for(engine: &str, artifacts: &str) -> Result<Box<dyn ComputeFactory>, String> {
    match engine {
        "native" => Ok(Box::new(NativeFactory)),
        "xla" => {
            let rt = Runtime::start(artifacts)
                .map_err(|e| format!("failed to start XLA runtime: {e}"))?;
            let handle = rt.handle();
            // Keep the runtime's service thread alive for the process.
            std::mem::forget(rt);
            Ok(Box::new(XlaFactory { handle }))
        }
        other => Err(format!("unknown engine '{other}'")),
    }
}

fn serve_cli() -> Cli {
    Cli::new(
        "dglmnet serve",
        "serve a saved model over TCP (newline-delimited JSON)",
    )
    .required("model", "path to a model JSON written by `train --save-model`")
    .flag("addr", "127.0.0.1:7878", "listen address (port 0 = ephemeral)")
    .flag("engine", "native", "compute engine: native | xla (needs artifacts/)")
    .flag("artifacts", "artifacts", "artifacts directory for --engine xla")
    .flag("io-threads", "8", "connection-handling threads")
    .flag("batch-workers", "2", "micro-batch scoring threads")
    .flag("max-batch", "256", "max rows coalesced per micro-batch")
    .flag("max-wait-us", "200", "micro-batch linger in microseconds")
}

fn cmd_serve(argv: &[String]) -> i32 {
    let cli = serve_cli();
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(CliError::HelpRequested) => {
            println!("{}", cli.help_text());
            return 0;
        }
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cli.help_text());
            return 2;
        }
    };
    let registry = Arc::new(ModelRegistry::new());
    let version = match registry.load_path(args.get("model")) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("failed to load model: {e}");
            return 1;
        }
    };
    let factory = match factory_for(args.get("engine"), args.get("artifacts")) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let snap = registry.get(version).expect("just loaded");
    let scorer = Arc::new(Scorer::new(Arc::clone(&registry), factory));
    let cfg = ServerConfig {
        addr: args.get("addr").to_string(),
        io_threads: args.get_usize("io-threads"),
        batcher: BatcherConfig {
            max_batch_rows: args.get_usize("max-batch"),
            max_wait: std::time::Duration::from_micros(args.get_u64("max-wait-us")),
            workers: args.get_usize("batch-workers"),
        },
    };
    let handle = match serve(scorer, cfg) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("failed to bind: {e}");
            return 1;
        }
    };
    println!(
        "serving {} v{} (loss={}, {} non-zero of {} features) on {} | engine={} | \
         swap with {{\"op\":\"swap-model\"}}",
        args.get("model"),
        version,
        snap.model.kind.name(),
        snap.model.nnz(),
        snap.model.p,
        handle.addr(),
        args.get("engine"),
    );
    // Serve until the process is killed.
    loop {
        std::thread::park();
    }
}

fn bench_serve_cli() -> Cli {
    Cli::new(
        "dglmnet bench-serve",
        "closed-loop load generator: QPS + p50/p99 latency",
    )
    .flag("addr", "", "target server (empty: spawn an in-process server)")
    .flag("model", "", "model for the in-process server (empty: synthetic)")
    .flag("engine", "native", "in-process server engine: native | xla")
    .flag("artifacts", "artifacts", "artifacts directory for --engine xla")
    .flag("threads", "4", "client threads (acceptance bar: ≥ 4)")
    .flag("requests", "2000", "requests per client thread")
    .flag("rows", "4", "rows per request")
    .flag("nnz", "32", "non-zeros per row")
    .flag("p", "65536", "feature-space width for synthetic rows/model")
    .flag("seed", "1", "random seed")
}

fn cmd_bench_serve(argv: &[String]) -> i32 {
    let cli = bench_serve_cli();
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(CliError::HelpRequested) => {
            println!("{}", cli.help_text());
            return 0;
        }
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cli.help_text());
            return 2;
        }
    };
    let cfg = LoadgenConfig {
        threads: args.get_usize("threads"),
        requests_per_thread: args.get_usize("requests"),
        rows_per_request: args.get_usize("rows"),
        nnz_per_row: args.get_usize("nnz"),
        p: args.get_usize("p"),
        seed: args.get_u64("seed"),
    };
    // Spawn an in-process server unless an external address was given.
    let mut local = None;
    let addr = if args.get("addr").is_empty() {
        let model = if args.get("model").is_empty() {
            synthetic_model(cfg.p, (cfg.p / 100).max(16), cfg.seed)
        } else {
            match GlmModel::load(args.get("model")) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("failed to load model: {e}");
                    return 1;
                }
            }
        };
        let factory = match factory_for(args.get("engine"), args.get("artifacts")) {
            Ok(f) => f,
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        };
        let registry = Arc::new(ModelRegistry::with_model(model));
        let scorer = Arc::new(Scorer::new(registry, factory));
        let handle = match serve(
            scorer,
            ServerConfig {
                addr: "127.0.0.1:0".to_string(),
                io_threads: cfg.threads + 2,
                ..Default::default()
            },
        ) {
            Ok(h) => h,
            Err(e) => {
                eprintln!("failed to start in-process server: {e}");
                return 1;
            }
        };
        let addr = handle.addr().to_string();
        local = Some(handle);
        addr
    } else {
        args.get("addr").to_string()
    };
    println!(
        "bench-serve: target {addr} | {} threads × {} requests, {} rows/req × {} nnz",
        cfg.threads, cfg.requests_per_thread, cfg.rows_per_request, cfg.nnz_per_row
    );
    let report = match run_loadgen(addr.as_str(), cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("loadgen failed: {e}");
            return 1;
        }
    };
    report.print();
    let mut t = Table::new(&["threads", "qps", "rows/s", "p50 ms", "p99 ms", "max ms"]);
    t.row(&[
        report.threads.to_string(),
        format!("{:.0}", report.qps()),
        format!("{:.0}", report.rows_per_sec()),
        format!("{:.3}", report.hist.quantile_ns(0.50) as f64 / 1e6),
        format!("{:.3}", report.hist.quantile_ns(0.99) as f64 / 1e6),
        format!("{:.3}", report.hist.max_ns() as f64 / 1e6),
    ]);
    t.print();
    if let Some(mut h) = local {
        h.stop();
    }
    0
}

fn cmd_trace_report(argv: &[String]) -> i32 {
    let cli = Cli::new(
        "dglmnet trace-report",
        "render per-rank phase totals, the per-iteration × per-rank \
         breakdown, and the iteration-skew table from a run log written by \
         `train --trace-out`",
    )
    .flag("file", "", "run-log NDJSON path (may also be given positionally)");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(CliError::HelpRequested) => {
            println!("{}", cli.help_text());
            return 0;
        }
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cli.help_text());
            return 2;
        }
    };
    let path = if !args.get("file").is_empty() {
        args.get("file").to_string()
    } else if let Some(p) = args.positional().first() {
        p.clone()
    } else {
        eprintln!("usage: dglmnet trace-report <run.ndjson>\n\n{}", cli.help_text());
        return 2;
    };
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("failed to read {path}: {e}");
            return 1;
        }
    };
    match dglmnet::obs::runlog::parse(&text) {
        Ok(log) => {
            print!("{}", dglmnet::obs::runlog::report(&log));
            0
        }
        Err(e) => {
            eprintln!("failed to parse run log {path}: {e}");
            1
        }
    }
}

fn cmd_summary(argv: &[String]) -> i32 {
    let cli = Cli::new("dglmnet summary", "Table 1: dataset summaries")
        .flag("scale", "0.25", "synthetic corpus scale factor")
        .flag("seed", "1", "random seed");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(CliError::HelpRequested) => {
            println!("{}", cli.help_text());
            return 0;
        }
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let mut t = Table::new(&[
        "dataset",
        "size",
        "#examples (train/test/validation)",
        "#features",
        "nnz",
        "avg nonzeros",
    ]);
    for (_, splits) in harness::corpora(args.get_f64("scale"), args.get_u64("seed")) {
        let s = splits.summary();
        t.row(&[
            s.name.clone(),
            format!("{:.1} MiB", s.bytes as f64 / (1024.0 * 1024.0)),
            format!("{} / {} / {}", s.n_train, s.n_test, s.n_validation),
            s.p.to_string(),
            format!("{:.2e}", s.nnz as f64),
            format!("{:.0}", s.avg_nonzeros),
        ]);
    }
    t.print();
    0
}

