#!/usr/bin/env bash
# End-to-end cluster scenarios from the shipped binary, driven by the CI
# matrix (scenario × thread count). Each scenario spawns real `dglmnet
# worker` processes on loopback, runs the coordinator, and asserts on its
# output. Usage: e2e.sh <scenario> [threads]
set -euo pipefail

SCENARIO="${1:?usage: e2e.sh <scenario> [threads]}"
THREADS="${2:-1}"
BIN=./target/release/dglmnet

# Spawn N workers on base_port+1..base_port+N (rank 0 = the coordinator).
spawn_workers() {
  local base=$1 count=$2
  shift 2
  for i in $(seq 1 "$count"); do
    "$BIN" worker --listen "127.0.0.1:$((base + i))" "$@" &
  done
  sleep 1
}

# The --cluster address list for base_port + N workers.
cluster_list() {
  local base=$1 count=$2
  local list="127.0.0.1:$base"
  for i in $(seq 1 "$count"); do list="$list,127.0.0.1:$((base + i))"; done
  echo "$list"
}

# Pull "objective=X" out of the coordinator's done line.
objective_of() {
  sed -n 's/^done:.*objective=\([0-9.eE+-]*\).*/\1/p' "$1" | head -1
}

case "$SCENARIO" in
  train-bsp)
    # 1 coordinator + 3 workers over loopback TCP: the multi-process
    # runtime end to end.
    spawn_workers 7100 3
    "$BIN" train \
      --cluster "$(cluster_list 7100 3)" \
      --dataset epsilon_like --scale 0.1 --seed 1 \
      --loss logistic --l1 0.5 --max-iters 10 --eval-every 0 \
      | tee train.log
    wait
    grep -q "^done:" train.log
    ;;

  train-alb)
    # The asynchronous path with an injected straggler: the per-rank load
    # table must appear (the suites assert the cut-off itself).
    spawn_workers 7110 3
    "$BIN" train \
      --cluster "$(cluster_list 7110 3)" \
      --dataset epsilon_like --scale 0.1 --seed 1 \
      --loss logistic --l1 0.5 --max-iters 20 --eval-every 0 \
      --alb-kappa 0.75 --straggler-delays-ms 0,0,40,0 --chunk 8 \
      | tee train_alb.log
    wait
    grep -q "^done:" train_alb.log
    grep -q "per-rank load" train_alb.log
    ;;

  path)
    # Distributed λ-path sweep: warm starts + KKT screening over 2 workers.
    spawn_workers 7120 2
    "$BIN" path \
      --cluster "$(cluster_list 7120 2)" \
      --dataset webspam_like --scale 0.1 --seed 1 \
      --loss logistic --lambdas 4.0,1.0,0.25,0.0625 --l2 0.0 \
      --max-iters 30 \
      | tee path.log
    wait
    grep -q "^best:" path.log
    grep -q -- "<- best" path.log
    ;;

  hybrid)
    # Hybrid parallelism: the same converged job single-threaded and with
    # --threads T per rank. The per-rank table must report the thread count
    # and the T-threaded objective must match the T=1 log (one convex
    # optimum; both runs converge).
    spawn_workers 7130 2
    "$BIN" train \
      --cluster "$(cluster_list 7130 2)" \
      --dataset epsilon_like --scale 0.1 --seed 1 \
      --loss logistic --l1 0.5 --l2 0.1 --max-iters 80 --eval-every 0 \
      --threads 1 \
      | tee train_t1.log
    wait
    grep -q "^done:" train_t1.log

    spawn_workers 7140 2
    "$BIN" train \
      --cluster "$(cluster_list 7140 2)" \
      --dataset epsilon_like --scale 0.1 --seed 1 \
      --loss logistic --l1 0.5 --l2 0.1 --max-iters 80 --eval-every 0 \
      --threads "$THREADS" \
      | tee train_tN.log
    wait
    grep -q "^done:" train_tN.log

    # Every rank's row of the per-rank table reports the thread count
    # (table columns: rank | cd updates | passes | cutoffs | sent MiB |
    # msgs | sync wait | threads | upd/thread).
    rows=$(awk -F'|' -v t="$THREADS" \
      'NF >= 11 { gsub(/ /, "", $2); gsub(/ /, "", $9);
                  if ($2 ~ /^[0-9]+$/ && $9 == t) c++ }
       END { print c + 0 }' train_tN.log)
    if [ "$rows" -ne 3 ]; then
      echo "expected 3 per-rank rows reporting threads=$THREADS, got $rows" >&2
      exit 1
    fi

    obj1=$(objective_of train_t1.log)
    objN=$(objective_of train_tN.log)
    awk -v a="$obj1" -v b="$objN" 'BEGIN {
      if (a == "" || b == "") { print "missing objective"; exit 1 }
      d = (a - b) / a; if (d < 0) d = -d
      if (d > 1e-3) {
        printf "hybrid objective drifted: T=1 %s vs T=N %s (rel gap %g)\n", a, b, d
        exit 1
      }
    }'
    ;;

  trace-e2e)
    # Observability pipeline: a 2-worker cluster run writes the merged run
    # log (--trace-out), and trace-report renders per-iteration / per-rank
    # phase breakdowns from it. Asserts every rank shipped spans and that
    # the journal/rank-load sync reconciliation lines appear.
    spawn_workers 7150 2
    "$BIN" train \
      --cluster "$(cluster_list 7150 2)" \
      --dataset epsilon_like --scale 0.1 --seed 1 \
      --loss logistic --l1 0.5 --max-iters 10 --eval-every 0 \
      --log-level debug --trace-out run.ndjson \
      | tee trace.log
    wait
    grep -q "^done:" trace.log
    grep -q "run log written to run.ndjson" trace.log
    grep -q "comm by tag:" trace.log

    # The NDJSON must carry the header, one rank-load record per rank, and
    # spans from every rank (coordinator = 0, workers = 1, 2).
    grep -q '"type":"run"' run.ndjson
    for r in 0 1 2; do
      grep '"type":"rank"' run.ndjson | grep -q "\"rank\":$r"
      grep '"type":"span"' run.ndjson | grep -q "\"rank\":$r"
    done

    "$BIN" trace-report run.ndjson | tee report.log
    grep -q "per-rank phase totals" report.log
    grep -q "per-iteration per-rank phase breakdown" report.log
    grep -q "iteration skew" report.log
    grep -q "linesearch" report.log
    for r in 0 1 2; do
      grep -q "sync reconcile rank $r:" report.log
    done
    ;;

  chaos-e2e)
    # Fault tolerance end to end: a checkpointed cluster survives a worker
    # that kills itself mid-run (--die-after), because the dead rank's
    # restart rejoins on the same port and the coordinator re-ships a
    # resume job from the latest checkpoint. A control run without
    # checkpoints must fail fast with the typed peer-loss error.
    # A slow runner must not push the restart past the probe deadline.
    export DGLMNET_REJOIN_WINDOW_SECS=30
    rm -rf ckpts && mkdir -p ckpts
    "$BIN" worker --listen 127.0.0.1:7161 --die-after 2 > worker1.log 2>&1 &
    W1=$!
    "$BIN" worker --listen 127.0.0.1:7162 --rejoin > worker2.log 2>&1 &
    sleep 1
    "$BIN" train \
      --cluster 127.0.0.1:7160,127.0.0.1:7161,127.0.0.1:7162 \
      --dataset epsilon_like --scale 0.1 --seed 1 \
      --loss logistic --l1 0.5 --max-iters 10 --eval-every 0 \
      --checkpoint-dir ckpts --checkpoint-every 1 \
      > chaos.log 2>&1 &
    COORD=$!

    # Rank 1 kills itself at the start of iteration 3; its "restart" comes
    # back on the same port inside the coordinator's rejoin window.
    wait "$W1" || true
    "$BIN" worker --listen 127.0.0.1:7161 --rejoin > worker1b.log 2>&1 &

    wait "$COORD"
    cat chaos.log
    grep -q "^done:" chaos.log
    grep -q "recovery attempt" chaos.log
    # The surviving worker rode its --rejoin loop back to the accept loop
    # instead of dying with the job.
    grep -q "rejoining for a resume job" worker2.log
    ls ckpts/ | grep -q "^ckpt-"
    wait

    # Control: the same death without checkpoints is fatal — and typed.
    "$BIN" worker --listen 127.0.0.1:7165 --die-after 2 > worker3.log 2>&1 &
    "$BIN" worker --listen 127.0.0.1:7166 > worker4.log 2>&1 &
    sleep 1
    if "$BIN" train \
      --cluster 127.0.0.1:7164,127.0.0.1:7165,127.0.0.1:7166 \
      --dataset epsilon_like --scale 0.1 --seed 1 \
      --loss logistic --l1 0.5 --max-iters 10 --eval-every 0 \
      > chaos_fatal.log 2>&1; then
      echo "train must fail when a rank dies without checkpoints" >&2
      exit 1
    fi
    grep -q "hung up" chaos_fatal.log
    wait || true
    ;;

  convert-e2e)
    # Out-of-core ingestion end to end: convert the corpus to a binary
    # shard directory, train a 3-rank cluster from `shards:<dir>` (each
    # worker reads only its own block file), and pin the objective to the
    # text-ingest run of the identical job — the converter's hashed
    # partition matches the text path's, so the fits must agree.
    rm -rf shards-e2e
    "$BIN" convert --dataset epsilon_like --scale 0.1 --seed 1 \
      --blocks 3 --out shards-e2e | tee convert.log
    grep -q "^convert:" convert.log
    test -f shards-e2e/header.bin
    test -f shards-e2e/block-0002.bin

    spawn_workers 7170 2
    "$BIN" train \
      --cluster "$(cluster_list 7170 2)" \
      --dataset "shards:$PWD/shards-e2e" --scale 0.1 --seed 1 \
      --loss logistic --l1 0.5 --max-iters 10 --eval-every 0 \
      | tee train_shards.log
    wait
    grep -q "^done:" train_shards.log

    spawn_workers 7180 2
    "$BIN" train \
      --cluster "$(cluster_list 7180 2)" \
      --dataset epsilon_like --scale 0.1 --seed 1 \
      --loss logistic --l1 0.5 --max-iters 10 --eval-every 0 \
      | tee train_text.log
    wait
    grep -q "^done:" train_text.log

    objS=$(objective_of train_shards.log)
    objT=$(objective_of train_text.log)
    awk -v a="$objS" -v b="$objT" 'BEGIN {
      if (a == "" || b == "") { print "missing objective"; exit 1 }
      d = (a - b) / a; if (d < 0) d = -d
      if (d > 1e-6) {
        printf "shard-ingest objective drifted: shards %s vs text %s (rel gap %g)\n", a, b, d
        exit 1
      }
    }'
    ;;

  partition-e2e)
    # Partition-strategy seam end to end: train the same 3-rank cluster job
    # on the block-correlated corpus with the co-occurrence-clustered layout
    # and with the default hashed layout. The banner must name the chosen
    # strategy, every per-rank row must carry the cut diagnostic, and the
    # two layouts must converge to the same optimum (≤ 1e-3 relative —
    # the partition changes the iterates, not the convex problem).
    spawn_workers 7190 2
    "$BIN" train \
      --cluster "$(cluster_list 7190 2)" \
      --dataset block_correlated --scale 0.1 --seed 1 \
      --loss logistic --l1 0.5 --l2 0.1 --max-iters 60 --eval-every 0 \
      --partition cluster \
      | tee train_cluster_part.log
    wait
    grep -q "^done:" train_cluster_part.log
    grep -q "partition: strategy=cluster" train_cluster_part.log
    # The per-rank table's trailing cut column: one 0.xxx (or "-") entry
    # per rank row (table: rank | ... | threads | upd/thread | cut).
    rows=$(awk -F'|' 'NF >= 12 { gsub(/ /, "", $2); gsub(/ /, "", $11);
                      if ($2 ~ /^[0-9]+$/ && $11 ~ /^[0-9]\.[0-9]+$/) c++ }
           END { print c + 0 }' train_cluster_part.log)
    if [ "$rows" -ne 3 ]; then
      echo "expected 3 per-rank rows with a numeric cut column, got $rows" >&2
      exit 1
    fi

    spawn_workers 7200 2
    "$BIN" train \
      --cluster "$(cluster_list 7200 2)" \
      --dataset block_correlated --scale 0.1 --seed 1 \
      --loss logistic --l1 0.5 --l2 0.1 --max-iters 60 --eval-every 0 \
      | tee train_hashed_part.log
    wait
    grep -q "^done:" train_hashed_part.log
    grep -q "partition: strategy=hashed" train_hashed_part.log

    objC=$(objective_of train_cluster_part.log)
    objH=$(objective_of train_hashed_part.log)
    awk -v a="$objC" -v b="$objH" 'BEGIN {
      if (a == "" || b == "") { print "missing objective"; exit 1 }
      d = (a - b) / a; if (d < 0) d = -d
      if (d > 1e-3) {
        printf "partition layouts disagree: cluster %s vs hashed %s (rel gap %g)\n", a, b, d
        exit 1
      }
    }'
    ;;

  kernels-e2e)
    # Kernel-tier seam end to end (job-spec v9): the same 3-rank cluster job
    # under the strict default and under --fast-math. Both banners must name
    # their tier, and the reordered-accumulation run must stay within the
    # documented end-to-end tolerance (≤ 1e-4 relative) of the strict run.
    # Then the pin leg: a worker started with --fast-math off must REJECT a
    # --fast-math job with the pointed mismatch error instead of silently
    # solving on the wrong tier.
    spawn_workers 7210 2
    "$BIN" train \
      --cluster "$(cluster_list 7210 2)" \
      --dataset epsilon_like --scale 0.1 --seed 1 \
      --loss logistic --l1 0.5 --l2 0.1 --max-iters 20 --eval-every 0 \
      | tee train_strict.log
    wait
    grep -q "^done:" train_strict.log
    grep -q "kernels=strict" train_strict.log

    spawn_workers 7220 2
    "$BIN" train \
      --cluster "$(cluster_list 7220 2)" \
      --dataset epsilon_like --scale 0.1 --seed 1 \
      --loss logistic --l1 0.5 --l2 0.1 --max-iters 20 --eval-every 0 \
      --fast-math \
      | tee train_fast.log
    wait
    grep -q "^done:" train_fast.log
    grep -q "kernels=fast-math" train_fast.log

    objS=$(objective_of train_strict.log)
    objF=$(objective_of train_fast.log)
    awk -v a="$objS" -v b="$objF" 'BEGIN {
      if (a == "" || b == "") { print "missing objective"; exit 1 }
      d = (a - b) / a; if (d < 0) d = -d
      if (d > 1e-4) {
        printf "fast-math drifted past its tier: strict %s vs fast %s (rel gap %g)\n", a, b, d
        exit 1
      }
    }'

    # Pin leg: strict-pinned worker vs --fast-math job → pointed rejection.
    spawn_workers 7230 1 --fast-math off
    if "$BIN" train \
      --cluster "$(cluster_list 7230 1)" \
      --dataset epsilon_like --scale 0.1 --seed 1 \
      --loss logistic --l1 0.5 --max-iters 2 --eval-every 0 \
      --fast-math \
      > kernels_mismatch.log 2>&1; then
      echo "train must fail when a pinned worker rejects the kernel tier" >&2
      exit 1
    fi
    grep -q "rejected the job" kernels_mismatch.log
    grep -q "pinned to strict kernels" kernels_mismatch.log
    wait || true
    ;;

  *)
    echo "unknown scenario '$SCENARIO'" >&2
    exit 2
    ;;
esac
