//! Node-count scaling (miniature of Figures 7-8): time for d-GLMNET-ALB to
//! reach 2.5% relative suboptimality as the simulated cluster grows.
//!
//!     cargo run --release --example scaling

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use dglmnet::glm::loss::LossKind;
use dglmnet::harness::{self, RunConfig};
use dglmnet::solver::compute::NativeCompute;
use dglmnet::util::bench::Table;

fn main() {
    let scale = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.4);
    let splits = dglmnet::data::Corpus::webspam_like(scale, 19);
    let kind = LossKind::Logistic;
    let pen = harness::default_lambda("webspam_like", true);
    let f_star = harness::reference_optimum(&splits, kind, &pen);
    println!(
        "webspam-like n={} p={}; f* = {:.4}",
        splits.train.n(),
        splits.train.p(),
        f_star
    );

    let compute = NativeCompute::new(kind);
    let mut table = Table::new(&["nodes", "time to 2.5% (s)", "speedup vs 1 node", "comm MiB"]);
    let mut t1 = None;
    for nodes in [1usize, 2, 4, 8, 16] {
        let rc = RunConfig {
            kind,
            pen,
            nodes,
            max_iters: 60,
            eval_every: 0,
            seed: 5,
        };
        let fit = harness::run_dglmnet(&splits, &rc, &compute, Some(0.75));
        let t = fit
            .trace
            .time_to_suboptimality(f_star, 0.025)
            .unwrap_or(f64::NAN);
        if nodes == 1 {
            t1 = Some(t);
        }
        table.row(&[
            nodes.to_string(),
            format!("{t:.3}"),
            format!("{:.2}x", t1.unwrap_or(f64::NAN) / t),
            format!("{:.2}", fit.comm_bytes as f64 / (1024.0 * 1024.0)),
        ]);
    }
    table.print();
    println!("\n(speedup saturates as the block-diagonal Hessian model degrades and\ncommunication grows — the paper's Fig 7/8 observation)");
}
