//! Quickstart: train an L1-regularized logistic regression with distributed
//! coordinate descent on a small synthetic dataset, entirely through the
//! public API.
//!
//!     cargo run --release --example quickstart

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use dglmnet::coordinator::{fit_distributed, DistributedConfig};
use dglmnet::data::Corpus;
use dglmnet::glm::loss::LossKind;
use dglmnet::glm::regularizer::ElasticNet;
use dglmnet::metrics;
use dglmnet::solver::compute::NativeCompute;

fn main() {
    // 1. A dataset: the clickstream corpus at toy scale (see data::synth for
    //    the generator; any libsvm file works too via sparse::libsvm).
    let splits = Corpus::clickstream(0.1, 42);
    println!(
        "dataset: {} train examples, {} features, {:.1} avg nnz/example",
        splits.train.n(),
        splits.train.p(),
        splits.train.nnz() as f64 / splits.train.n() as f64
    );

    // 2. The model: logistic loss + L1 (lasso) penalty.
    let compute = NativeCompute::new(LossKind::Logistic);
    let penalty = ElasticNet::l1_only(0.5);

    // 3. Train with d-GLMNET on 4 simulated cluster nodes.
    let cfg = DistributedConfig {
        nodes: 4,
        max_iters: 30,
        ..Default::default()
    };
    let fit = fit_distributed(&splits.train, Some(&splits.test), &compute, &penalty, &cfg);

    // 4. Evaluate.
    let scores = splits.test.x.mul_vec(&fit.beta);
    println!(
        "objective {:.4} after {} iterations; {} of {} weights non-zero",
        fit.objective,
        fit.iters,
        metrics::nnz_weights(&fit.beta),
        fit.beta.len()
    );
    println!(
        "test auPRC {:.4}, ROC-AUC {:.4}",
        metrics::auprc(&splits.test.y, &scores),
        metrics::roc_auc(&splits.test.y, &scores)
    );
    println!(
        "communication: {:.2} KiB over {} messages",
        fit.comm_bytes as f64 / 1024.0,
        fit.comm_msgs
    );
    assert!(fit.objective.is_finite());
}
