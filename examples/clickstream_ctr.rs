//! END-TO-END DRIVER — the full three-layer system on a realistic workload.
//!
//! Click-through-rate prediction (the paper's yandex_ad scenario): a sparse,
//! heavily imbalanced clickstream corpus; L1-regularized logistic regression
//! trained by d-GLMNET-ALB across 8 simulated cluster nodes, with the
//! per-example GLM statistics and batched line-search objective executed
//! through the AOT-compiled Pallas/XLA artifacts (PJRT runtime) — Python is
//! not involved at any point of this run.
//!
//! Prints the paper's three evaluation series (relative suboptimality, test
//! auPRC, nnz vs time) and writes the trace JSON. Results are recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts && cargo run --release --example clickstream_ctr

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use dglmnet::coordinator::{fit_distributed, DistributedConfig};
use dglmnet::data::Corpus;
use dglmnet::glm::loss::LossKind;
use dglmnet::glm::regularizer::ElasticNet;
use dglmnet::harness;
use dglmnet::metrics;
use dglmnet::runtime::{Runtime, XlaCompute};
use dglmnet::solver::compute::NativeCompute;

fn main() {
    let scale = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.5);
    let splits = Corpus::clickstream(scale, 7);
    println!(
        "clickstream CTR: n={} p={} nnz={} positive rate {:.3}",
        splits.train.n(),
        splits.train.p(),
        splits.train.nnz(),
        splits.train.positive_rate()
    );

    let kind = LossKind::Logistic;
    let penalty = ElasticNet::l1_only(1.0);
    let cfg = DistributedConfig {
        nodes: 8,
        alb_kappa: Some(0.75),
        max_iters: 40,
        eval_every: 1,
        ..Default::default()
    };

    // L2/L1 layers: AOT Pallas artifacts through the PJRT runtime.
    let rt = match Runtime::start("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("XLA runtime unavailable ({e}); run `make artifacts` first.");
            std::process::exit(1);
        }
    };
    let xla = XlaCompute::new(rt.handle(), kind);

    let t0 = std::time::Instant::now();
    let fit = fit_distributed(&splits.train, Some(&splits.test), &xla, &penalty, &cfg);
    let wall = t0.elapsed();

    // Reference optimum for the suboptimality axis.
    let f_star = harness::reference_optimum(&splits, kind, &penalty);
    harness::print_convergence("clickstream (XLA engine)", &[&fit.trace], f_star);

    let scores = splits.test.x.mul_vec(&fit.beta);
    println!(
        "\nheadline: {:.2}s wall, objective {:.4} (f* {:.4}), test auPRC {:.4}, nnz {}/{}",
        wall.as_secs_f64(),
        fit.objective,
        f_star,
        metrics::auprc(&splits.test.y, &scores),
        metrics::nnz_weights(&fit.beta),
        fit.beta.len()
    );
    println!(
        "comm {:.2} MiB / {} msgs; time to 2.5% suboptimality: {:?}s",
        fit.comm_bytes as f64 / (1024.0 * 1024.0),
        fit.comm_msgs,
        fit.trace.time_to_suboptimality(f_star, 0.025)
    );

    // Cross-check the XLA path against the native oracle end-to-end.
    let native = NativeCompute::new(kind);
    let fit_native = fit_distributed(&splits.train, None, &native, &penalty, &cfg);
    let gap = (fit.objective - fit_native.objective).abs() / fit_native.objective;
    println!(
        "engine parity: xla {:.6} vs native {:.6} (relative gap {:.2e})",
        fit.objective, fit_native.objective, gap
    );
    assert!(gap < 1e-6, "XLA and native engines diverged");

    std::fs::write("clickstream_ctr_trace.json", fit.trace.to_json().dump())
        .expect("write trace");
    println!("trace written to clickstream_ctr_trace.json");
}
