//! Trust-region μ ablation (miniature of Figure 1): constant μ = 1 vs the
//! adaptive μ schedule on the clickstream corpus with L1 — adaptive μ should
//! dramatically improve sparsity at equal-or-better convergence.
//!
//!     cargo run --release --example mu_ablation

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use dglmnet::cluster::allreduce::AllReduceAlgo;
use dglmnet::coordinator::{fit_distributed, DistributedConfig};

use dglmnet::glm::loss::LossKind;
use dglmnet::glm::regularizer::ElasticNet;
use dglmnet::harness;
use dglmnet::metrics;
use dglmnet::solver::compute::NativeCompute;

fn main() {
    // Dense correlated features + many blocks = the conflict regime where
    // parallel block steps overshoot, the line search keeps picking α < 1,
    // and (without the trust region) sparsity-restoring steps to exactly 0
    // never complete — the paper's Fig 1 setting.
    let splits = dglmnet::data::synth::correlated_dense(
        &dglmnet::data::SynthConfig {
            n: 3000,
            p: 400,
            seed: 13,
        },
        0.6,
    )
    .split(300, 300);
    let kind = LossKind::Logistic;
    let pen = ElasticNet::l1_only(10.0);
    let compute = NativeCompute::new(kind);
    let f_star = harness::reference_optimum(&splits, kind, &pen);

    let base = DistributedConfig {
        nodes: 16,
        max_iters: 40,
        eval_every: 1,
        allreduce: AllReduceAlgo::Ring,
        ..Default::default()
    };

    let adaptive = fit_distributed(
        &splits.train,
        Some(&splits.test),
        &compute,
        &pen,
        &DistributedConfig {
            adaptive_mu: true,
            ..base.clone()
        },
    );
    let constant = fit_distributed(
        &splits.train,
        Some(&splits.test),
        &compute,
        &pen,
        &DistributedConfig {
            adaptive_mu: false,
            ..base
        },
    );

    let mut adaptive_trace = adaptive.trace.clone();
    adaptive_trace.algorithm = "adaptive-mu".into();
    let mut constant_trace = constant.trace.clone();
    constant_trace.algorithm = "constant-mu(1)".into();
    harness::print_convergence(
        "clickstream L1 (Fig 1 ablation)",
        &[&adaptive_trace, &constant_trace],
        f_star,
    );

    println!(
        "\nfinal: adaptive μ nnz = {}, constant μ nnz = {} (of {})",
        metrics::nnz_weights(&adaptive.beta),
        metrics::nnz_weights(&constant.beta),
        adaptive.beta.len()
    );
    println!(
        "final suboptimality: adaptive {:.3e}, constant {:.3e}",
        (adaptive.objective - f_star) / f_star,
        (constant.objective - f_star) / f_star
    );
}
