//! The end-to-end train → promote → serve story: train a model through the
//! public API, save it the way `train --save-model` does, serve it over TCP,
//! score requests over the socket, then hot-swap in a retrained model
//! without dropping the connection.
//!
//!     cargo run --release --example serving

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use std::sync::Arc;

use dglmnet::coordinator::{fit_distributed, DistributedConfig};
use dglmnet::data::Corpus;
use dglmnet::glm::loss::LossKind;
use dglmnet::glm::regularizer::ElasticNet;
use dglmnet::glm::GlmModel;
use dglmnet::serve::{serve, ModelRegistry, NativeFactory, Scorer, ServeClient, ServerConfig};
use dglmnet::solver::compute::NativeCompute;

fn train(l1: f64) -> GlmModel {
    let splits = Corpus::clickstream(0.05, 42);
    let compute = NativeCompute::new(LossKind::Logistic);
    let cfg = DistributedConfig {
        nodes: 4,
        max_iters: 15,
        eval_every: 0,
        ..Default::default()
    };
    let fit = fit_distributed(&splits.train, None, &compute, &ElasticNet::l1_only(l1), &cfg);
    GlmModel::new(LossKind::Logistic, fit.beta)
        .with_meta("dataset", &splits.train.name)
        .with_meta("l1", l1)
}

fn main() {
    // 1. Train and save — exactly what `dglmnet train --save-model` writes.
    let dir = std::env::temp_dir().join(format!("dglmnet_serving_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model_path = dir.join("model.json");
    let model = train(0.5);
    model.save(&model_path).unwrap();
    println!(
        "trained: {} non-zero of {} features -> {}",
        model.nnz(),
        model.p,
        model_path.display()
    );

    // 2. Promote into a registry and serve (ephemeral port for the demo;
    //    production would pass --addr 0.0.0.0:7878 to `dglmnet serve`).
    let registry = Arc::new(ModelRegistry::new());
    registry.load_path(&model_path).unwrap();
    let scorer = Arc::new(Scorer::new(Arc::clone(&registry), Box::new(NativeFactory)));
    let mut server = serve(
        scorer,
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            ..Default::default()
        },
    )
    .unwrap();
    println!("serving on {}", server.addr());

    // 3. Score requests over the socket, like an online CTR caller would.
    let mut client = ServeClient::connect(server.addr()).unwrap();
    let rows = vec![
        vec![(0u32, 1.0), (7, 0.5)],
        vec![(3, 2.0)],
        vec![], // empty row scores the intercept-free margin 0 -> p = 0.5
    ];
    let (version, probs) = client.predict(&rows).unwrap();
    println!("v{version} probabilities: {probs:?}");

    // 4. A retrain lands at the same path; promote it with zero downtime.
    train(2.0).save(&model_path).unwrap();
    let v2 = client.swap_model(None).unwrap(); // reload from the same path
    let (version, probs) = client.predict(&rows).unwrap();
    assert_eq!(version, v2);
    println!("after hot-swap: v{version} probabilities: {probs:?}");

    let health = client.health().unwrap();
    println!("health: {}", health.dump());

    server.stop();
    std::fs::remove_dir_all(&dir).ok();
}
