//! Spam filtering (the paper's webspam scenario): compare d-GLMNET,
//! d-GLMNET-ALB, ADMM and online truncated gradient on a sparse text-like
//! corpus with L1 regularization — a miniature of Figures 2-4.
//!
//!     cargo run --release --example spam_filter

// Human-facing harness output goes straight to the terminal; the
// disallowed-macros lint only polices library code.
#![allow(clippy::disallowed_macros)]

use dglmnet::glm::loss::LossKind;
use dglmnet::harness::{self, RunConfig};
use dglmnet::solver::compute::NativeCompute;

fn main() {
    let scale = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25);
    let splits = dglmnet::data::Corpus::webspam_like(scale, 11);
    println!(
        "webspam-like: n={} p={} nnz={}",
        splits.train.n(),
        splits.train.p(),
        splits.train.nnz()
    );

    let rc = RunConfig {
        kind: LossKind::Logistic,
        pen: harness::default_lambda("webspam_like", true),
        nodes: 8,
        max_iters: 25,
        eval_every: 1,
        seed: 3,
    };
    let compute = NativeCompute::new(rc.kind);

    let f_star = harness::reference_optimum(&splits, rc.kind, &rc.pen);

    let d = harness::run_dglmnet(&splits, &rc, &compute, None);
    let dalb = harness::run_dglmnet(&splits, &rc, &compute, Some(0.75));
    let admm = harness::run_admm(&splits, &rc, 1.0);
    let online = harness::run_online(&splits, &rc);

    harness::print_convergence(
        "webspam_like (L1)",
        &[&d.trace, &dalb.trace, &admm, &online],
        f_star,
    );

    println!("\nbest test auPRC:");
    for tr in [&d.trace, &dalb.trace, &admm, &online] {
        println!(
            "  {:<14} {:.4}   (final objective {:.4}, final nnz {})",
            tr.algorithm,
            harness::best_auprc(tr).unwrap_or(f64::NAN),
            tr.final_objective(),
            tr.points.last().map(|p| p.nnz).unwrap_or(0)
        );
    }
}
